"""OpenAI-schema conformance: golden request/response fixtures round-trip
through the real HTTP server (tests/golden_openai/*.json).

Each fixture carries a request and a structural response schema; leaves are
matchers (``__type`` / ``__const`` / ``__enum`` / ``__each`` + ``__len``)
so the goldens pin the *contract* — key sets, types, enums, list shapes —
without depending on what a randomly initialised toy model generates.
Streaming fixtures validate the first/last/all SSE chunks plus the
``data: [DONE]`` terminator.  CI runs this module as its own conformance
smoke job (see .github/workflows/ci.yml)."""
import http.client
import json
import urllib.error
import urllib.request
from pathlib import Path

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import InferenceEngine
from repro.serving.api import OpenAIServer
from repro.serving.server import ApiServer

GOLDEN = sorted((Path(__file__).parent / "golden_openai").glob("*.json"))
assert GOLDEN, "golden fixture directory is empty"


@pytest.fixture(scope="module")
def server():
    cfg = get_config("qwen3-0.6b-toy")
    engine = InferenceEngine(cfg, max_batch=4, cache_len=128)
    api = OpenAIServer(engine, "toy")
    srv = ApiServer(api, port=0)
    srv.start()
    yield srv
    srv.stop()
    api.client.stop()


@pytest.fixture(scope="module")
def vl_server():
    """Vision-model server for the ``chat_image_*`` fixtures: real encoder
    stubs (cheap work_iters), with the synthetic:// fixture URL registered
    in the in-process media store."""
    from repro.serving.media import register_url

    cfg = get_config("qwen3-vl-toy")
    engine = InferenceEngine(cfg, max_batch=4, cache_len=256,
                             vision_work_iters=1)
    register_url("synthetic://golden-image",
                 (np.arange(8 * 8 * 3) % 251)
                 .reshape(8, 8, 3).astype(np.uint8))
    api = OpenAIServer(engine, "toy-vl")
    srv = ApiServer(api, port=0)
    srv.start()
    yield srv
    srv.stop()
    api.client.stop()


# --------------------------------------------------------------------------- #
# structural matcher
# --------------------------------------------------------------------------- #
def match(schema, value, path="$"):
    """Return a list of mismatch strings (empty = conforms)."""
    if isinstance(schema, dict) and "__type" in schema:
        kinds = {"string": str, "int": int, "number": (int, float),
                 "bool": bool, "null": type(None)}
        kind = schema["__type"]
        if kind == "any":
            return []
        if not isinstance(value, kinds[kind]) or (
                kind in ("int", "number") and isinstance(value, bool)):
            return [f"{path}: expected {kind}, got {type(value).__name__}"]
        return []
    if isinstance(schema, dict) and "__const" in schema:
        ok = value == schema["__const"]
        return [] if ok else [f"{path}: expected {schema['__const']!r}, "
                              f"got {value!r}"]
    if isinstance(schema, dict) and "__enum" in schema:
        ok = value in schema["__enum"]
        return [] if ok else [f"{path}: {value!r} not in {schema['__enum']}"]
    if isinstance(schema, dict) and "__each" in schema:
        if not isinstance(value, list):
            return [f"{path}: expected list, got {type(value).__name__}"]
        errs = []
        want_len = schema.get("__len")
        if want_len is not None and len(value) != want_len:
            errs.append(f"{path}: expected {want_len} items, "
                        f"got {len(value)}")
        for i, item in enumerate(value):
            errs += match(schema["__each"], item, f"{path}[{i}]")
        return errs
    if isinstance(schema, dict):
        if not isinstance(value, dict):
            return [f"{path}: expected object, got {type(value).__name__}"]
        errs = []
        for key, sub in schema.items():
            if key not in value:
                errs.append(f"{path}.{key}: missing")
            else:
                errs += match(sub, value[key], f"{path}.{key}")
        return errs
    return [] if value == schema else [f"{path}: expected {schema!r}, "
                                       f"got {value!r}"]


def _request_json(server, fixture):
    url = f"http://127.0.0.1:{server.port}{fixture['path']}"
    if fixture["method"] == "GET":
        req = urllib.request.Request(url)
    else:
        req = urllib.request.Request(
            url, data=json.dumps(fixture["request"]).encode(),
            headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=120) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _request_sse(server, fixture):
    conn = http.client.HTTPConnection("127.0.0.1", server.port, timeout=120)
    conn.request("POST", fixture["path"],
                 body=json.dumps(fixture["request"]).encode(),
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    raw = resp.read().decode()
    conn.close()
    events = [line[len("data: "):] for line in raw.split("\n\n")
              if line.startswith("data: ")]
    assert events and events[-1] == "[DONE]", raw[:400]
    return resp.status, [json.loads(e) for e in events[:-1]]


@pytest.mark.parametrize("path", GOLDEN, ids=lambda p: p.stem)
def test_golden_fixture(server, vl_server, path):
    # chat_image_* fixtures need the vision model; everything else runs
    # against the text-only server
    server = vl_server if path.stem.startswith("chat_image") else server
    fixture = json.loads(path.read_text())
    if fixture.get("stream"):
        status, chunks = _request_sse(server, fixture)
        assert status == fixture["status"]
        assert chunks, "no SSE chunks before [DONE]"
        errs = []
        if "first_chunk" in fixture:
            errs += match(fixture["first_chunk"], chunks[0], "first")
        if "last_chunk" in fixture:
            errs += match(fixture["last_chunk"], chunks[-1], "last")
        if "all_chunks" in fixture:
            for i, chunk in enumerate(chunks):
                errs += match(fixture["all_chunks"], chunk, f"chunk[{i}]")
        assert not errs, errs[:8]
    else:
        status, body = _request_json(server, fixture)
        assert status == fixture["status"], body
        errs = match(fixture["response"], body)
        assert not errs, errs[:8]


# --------------------------------------------------------------------------- #
# semantic checks the structural goldens cannot express
# --------------------------------------------------------------------------- #
def test_greedy_n_choices_identical(server):
    fixture = {
        "method": "POST", "path": "/v1/chat/completions",
        "request": {"messages": [{"role": "user", "content": "same"}],
                    "max_tokens": 4, "n": 3},
    }
    _, body = _request_json(server, fixture)
    texts = {c["message"]["content"] for c in body["choices"]}
    assert len(body["choices"]) == 3 and len(texts) == 1
    assert body["usage"]["completion_tokens"] == 12


def test_chat_logprobs_are_normalised(server):
    _, body = _request_json(server, {
        "method": "POST", "path": "/v1/chat/completions",
        "request": {"messages": [{"role": "user", "content": "lp"}],
                    "max_tokens": 3, "logprobs": True, "top_logprobs": 3},
    })
    for entry in body["choices"][0]["logprobs"]["content"]:
        assert entry["logprob"] <= 0.0
        tops = [t["logprob"] for t in entry["top_logprobs"]]
        assert tops == sorted(tops, reverse=True)
        # greedy sampling: the chosen token is the argmax
        assert abs(entry["logprob"] - tops[0]) < 1e-5


def test_usage_chunk_matches_blocking_usage(server):
    req = {"messages": [{"role": "user", "content": "usage parity"}],
           "max_tokens": 5}
    _, blocking = _request_json(server, {
        "method": "POST", "path": "/v1/chat/completions", "request": req})
    _, chunks = _request_sse(server, {
        "path": "/v1/chat/completions",
        "request": {**req, "stream": True,
                    "stream_options": {"include_usage": True}}})
    assert chunks[-1]["usage"] == blocking["usage"]
    assert chunks[-1]["choices"] == []
    # chunks before the usage chunk carry a null usage placeholder
    assert all(c["usage"] is None for c in chunks[:-1])


def test_out_of_range_sampler_params_rejected(server):
    """Codec-side sampler hardening: every out-of-range top_p/top_k/min_p/
    seed gets the structured envelope with the offending param named."""
    cases = [({"top_p": 0.0}, "top_p"), ({"top_p": 1.01}, "top_p"),
             ({"top_k": -1}, "top_k"), ({"min_p": 1.0}, "min_p"),
             ({"min_p": -0.5}, "min_p"), ({"seed": -1}, "seed"),
             ({"seed": 1.5}, "seed"), ({"top_k": "a"}, "top_k")]
    for extra, param in cases:
        status, body = _request_json(server, {
            "method": "POST", "path": "/v1/chat/completions",
            "request": {"messages": [{"role": "user", "content": "x"}],
                        "max_tokens": 2, **extra},
        })
        assert status == 400, (extra, body)
        assert body["error"]["param"] == param


def test_completions_echo_semantics(server):
    """echo=true: response text leads with the decoded prompt; the logprobs
    block covers prompt + completion tokens, the first prompt entry is null
    (nothing to condition on), prompt alternatives are null, and
    text_offset strictly accumulates over the *returned* text."""
    prompt = "echo me"
    _, body = _request_json(server, {
        "method": "POST", "path": "/v1/completions",
        "request": {"prompt": prompt, "max_tokens": 4, "logprobs": 1,
                    "echo": True},
    })
    choice = body["choices"][0]
    assert choice["text"].startswith(prompt)
    lp = choice["logprobs"]
    n_prompt = body["usage"]["prompt_tokens"]
    assert len(lp["tokens"]) == n_prompt + 4
    assert lp["token_logprobs"][0] is None
    for v in lp["token_logprobs"][1:]:
        assert isinstance(v, float) and v <= 0.0
    assert all(t is None for t in lp["top_logprobs"][:n_prompt])
    assert all(t is not None for t in lp["top_logprobs"][n_prompt:])
    offs = lp["text_offset"]
    assert offs[0] == 0 and offs == sorted(offs)
    assert "".join(lp["tokens"]) == choice["text"]
    # without logprobs, echo still prefixes the text
    _, plain = _request_json(server, {
        "method": "POST", "path": "/v1/completions",
        "request": {"prompt": prompt, "max_tokens": 4, "echo": True},
    })
    assert plain["choices"][0]["text"] == choice["text"]
    assert plain["choices"][0]["logprobs"] is None


def test_seeded_requests_replay_with_stable_fingerprint(server):
    """`seed` + unchanged `system_fingerprint` ⇒ identical completions —
    the OpenAI determinism contract, backed by per-request device-resident
    PRNG key streams."""
    req = {"messages": [{"role": "user", "content": "determinism"}],
           "max_tokens": 8, "temperature": 1.0, "top_p": 0.8, "seed": 123,
           "logprobs": True}

    def tokens(body):
        # toy-vocab ids above 255 decode to empty text (and empty bytes), so
        # compare the per-token logprob floats — a bit-exact fingerprint of
        # the sampled id sequence
        return [e["logprob"]
                for e in body["choices"][0]["logprobs"]["content"]]

    _, a = _request_json(server, {
        "method": "POST", "path": "/v1/chat/completions", "request": req})
    _, b = _request_json(server, {
        "method": "POST", "path": "/v1/chat/completions", "request": req})
    assert a["system_fingerprint"] == b["system_fingerprint"]
    assert a["system_fingerprint"].startswith("fp_")
    assert tokens(a) == tokens(b)
    # an unseeded stochastic request is NOT replayed (fresh per-request key)
    del req["seed"]
    _, c = _request_json(server, {
        "method": "POST", "path": "/v1/chat/completions", "request": req})
    _, d = _request_json(server, {
        "method": "POST", "path": "/v1/chat/completions", "request": req})
    assert tokens(c) != tokens(d)


def test_negative_top_logprobs_rejected(server):
    status, body = _request_json(server, {
        "method": "POST", "path": "/v1/chat/completions",
        "request": {"messages": [{"role": "user", "content": "x"}],
                    "logprobs": True, "top_logprobs": -1},
    })
    assert status == 400
    assert body["error"]["param"] == "top_logprobs"


def test_multi_prompt_submit_failure_leaks_no_slots(server):
    """If a later prompt of a multi-prompt completion is rejected at
    submit, the earlier prompts' handles are aborted — a 400 must not
    leave a decode slot burning to budget exhaustion."""
    import time as _time

    eng = server.api.engine
    status, body = _request_json(server, {
        "method": "POST", "path": "/v1/completions",
        "request": {"prompt": ["fine prompt", "x" * 4096],   # 2nd too long
                    "max_tokens": 100_000},
    })
    assert status == 400 and "error" in body
    deadline = _time.monotonic() + 60
    while _time.monotonic() < deadline:
        if (eng.pool.num_free == eng.pool.max_batch
                and not eng.scheduler.has_work):
            break
        _time.sleep(0.05)
    assert eng.pool.num_free == eng.pool.max_batch, "leaked a decode slot"
    assert eng.scheduler.stats.aborted >= 1


def test_stream_reassembles_to_blocking_text(server):
    req = {"messages": [{"role": "user", "content": "reassemble me"}],
           "max_tokens": 6}
    _, blocking = _request_json(server, {
        "method": "POST", "path": "/v1/chat/completions", "request": req})
    _, chunks = _request_sse(server, {
        "path": "/v1/chat/completions", "request": {**req, "stream": True}})
    text = "".join(c["choices"][0]["delta"].get("content", "")
                   for c in chunks if c["choices"])
    assert text == blocking["choices"][0]["message"]["content"]
