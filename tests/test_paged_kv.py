"""Paged KV cache (DESIGN_paged_kv.md): allocator/COW property tests, the
dense-vs-paged bit-exactness gates, zero-copy COW prefix admission, paged
snapshot/resume, int8 KV, and interpret-mode kernel validation.

The allocator property test uses ``hypothesis`` when installed and degrades
to a seeded stdlib-``random`` sweep otherwise (same op machine either way),
so the COW invariants are always exercised in tier-1.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import InferenceEngine
from repro.core.paged_kv import PageAllocator, PagedKVPool, PagePoolExhausted
from repro.core.request import FinishReason, Request, SamplingParams
from repro.kernels.ref import decode_attention_ref, paged_attention_ref
from repro.serving.tokenizer import ByteTokenizer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                        # pragma: no cover
    HAVE_HYPOTHESIS = False

TOK = ByteTokenizer()


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3-0.6b-toy")


def _req(text, max_tokens=8, deadline_ms=None):
    return Request(prompt_tokens=TOK.encode(text),
                   sampling=SamplingParams(max_tokens=max_tokens),
                   deadline_ms=deadline_ms)


def _outputs(eng, reqs):
    eng.generate(reqs)
    assert all(r.finish_reason in (FinishReason.STOP, FinishReason.LENGTH)
               for r in reqs)
    return [list(r.output_tokens) for r in reqs]


# --------------------------------------------------------------------------- #
# allocator / COW property test (satellite: hypothesis w/ seeded fallback)
# --------------------------------------------------------------------------- #
def _run_allocator_machine(seed: int, steps: int = 120) -> None:
    """Random walk over allocate / share / free / COW-split against a pure
    host model (owner -> page list), checking after every op:

      * refcount conservation — allocator refcounts == model reference
        counts, page for page
      * no page aliased by two writers — a page is writable iff its
        refcount is 1, so any page held by two owners must have ref >= 2
      * free-list integrity — the free list is exactly the unreferenced
        non-reserved ids, duplicate-free; reserved ids are never handed out
    """
    rng = random.Random(seed)
    num_pages, reserved = rng.randint(6, 24), rng.randint(0, 3)
    if num_pages <= reserved:
        num_pages = reserved + 2
    alloc = PageAllocator(num_pages, reserved=reserved)
    owners = {}                               # owner id -> list of page ids
    next_owner = 0

    def check():
        refs = {}
        for pages in owners.values():
            for p in pages:
                refs[p] = refs.get(p, 0) + 1
        for p in range(num_pages):
            assert alloc.refcount(p) == refs.get(p, 0), (
                f"refcount drift on page {p}")
            if p < reserved:
                assert refs.get(p, 0) == 0    # reserved never handed out
        holders = {p: sum(p in pages for pages in owners.values())
                   for p in refs}
        for p, n in holders.items():
            if n >= 2:                         # aliased -> not writable
                assert alloc.refcount(p) >= 2
        free = alloc._free
        assert len(free) == len(set(free)), "duplicate free-list entry"
        assert set(free) == {p for p in range(reserved, num_pages)
                             if refs.get(p, 0) == 0}, "free-list drift"

    for _ in range(steps):
        op = rng.choice(("alloc", "share", "free", "cow", "alloc", "share"))
        if op == "alloc":
            if alloc.num_free:
                owners.setdefault(next_owner, []).append(alloc.alloc())
                next_owner += 1
            else:
                with pytest.raises(PagePoolExhausted):
                    alloc.alloc()
        elif op == "share" and owners:
            src = rng.choice([p for ps in owners.values() for p in ps])
            alloc.incref(src)
            owners.setdefault(next_owner, []).append(src)
            next_owner += 1
        elif op == "free" and owners:
            key = rng.choice(list(owners))
            for p in owners.pop(key):
                alloc.decref(p)
        elif op == "cow" and owners:
            # split the first aliased page found: writer gets a fresh page,
            # the old one stays with its other owners (alloc-then-decref,
            # the same order ensure_decode_capacity uses)
            for key, pages in owners.items():
                idx = next((i for i, p in enumerate(pages)
                            if alloc.refcount(p) > 1), None)
                if idx is not None and alloc.num_free:
                    old = pages[idx]
                    pages[idx] = alloc.alloc()
                    alloc.decref(old)
                    break
        check()
    stats = alloc.stats
    assert stats.allocs >= stats.frees
    assert stats.full_copies == 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_allocator_cow_invariants(seed):
        _run_allocator_machine(seed)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_allocator_cow_invariants(seed):
        _run_allocator_machine(seed, steps=160)


def test_allocator_guards_double_free_and_foreign_incref():
    alloc = PageAllocator(4, reserved=1)
    p = alloc.alloc()
    with pytest.raises(AssertionError):
        alloc.incref(p + 1 if p + 1 < 4 else p - 1)   # unowned page
    alloc.decref(p)
    with pytest.raises(AssertionError):
        alloc.decref(p)


# --------------------------------------------------------------------------- #
# bit-exactness gates: paged decode == dense decode under greedy
# --------------------------------------------------------------------------- #
PROMPTS = ["the paged pool must reproduce the dense pool bit for bit",
           "second request, different length",
           "third one " * 4,
           "a", "fifth prompt with some more tokens in it"]


def _dense_outputs(cfg, **kw):
    eng = InferenceEngine(cfg, max_batch=4, cache_len=128, **kw)
    return _outputs(eng, [_req(p, max_tokens=10) for p in PROMPTS])


@pytest.mark.parametrize("page_size", [128, 16])
def test_paged_fp_bit_identical_to_dense(cfg, page_size):
    """The headline acceptance gate: with fp KV, paged greedy decode matches
    the dense ring token-for-token — both at ``page_size == cache_len``
    (identity page tables, the 'paging is free' case) and at a small page
    size (lazy tail allocation + table-gathered attention)."""
    dense = _dense_outputs(cfg)
    eng = InferenceEngine(cfg, max_batch=4, cache_len=128,
                          kv_layout="paged", kv_page_size=page_size)
    paged = _outputs(eng, [_req(p, max_tokens=10) for p in PROMPTS])
    assert paged == dense
    occ = eng.pool.page_occupancy()
    assert occ["pinned"] == 0                 # all slots retired
    assert occ["total"] == occ["free"] + occ["reclaimable"]
    assert eng.pool.stats.full_copies == 0


def test_paged_int8_decodes_and_stays_close(cfg):
    """int8 KV is lossy by design: the gate is completion + bounded drift of
    the first decoded token's distribution, not bit-identity."""
    eng = InferenceEngine(cfg, max_batch=4, cache_len=128,
                          kv_layout="paged", kv_page_size=16,
                          kv_dtype="int8")
    outs = _outputs(eng, [_req(p, max_tokens=10) for p in PROMPTS])
    assert all(len(o) == 10 for o in outs)


# --------------------------------------------------------------------------- #
# COW prefix sharing: admission maps pages, never copies
# --------------------------------------------------------------------------- #
def test_cow_prefix_hit_does_zero_copies(cfg):
    """The COW acceptance gate, asserted on allocator counters (not timing):
    a second request sharing a 64-token prefix admits by mapping the cached
    pages (refcount bump) and allocates fresh pages only from the
    divergence point; ``full_copies`` stays 0 and refcounts balance."""
    eng = InferenceEngine(cfg, max_batch=2, cache_len=128,
                          kv_layout="paged", kv_page_size=16)
    base = "shared prefix " * 8               # >= 64 tokens of shared prefix
    r1 = _req(base + "tail one", max_tokens=6)
    eng.generate([r1])
    allocs_before = eng.pool.stats.allocs

    r2 = _req(base + "tail TWO!", max_tokens=6)
    eng.generate([r2])
    assert r2.cached_prefix_len >= 64         # the prefix cache actually hit
    fresh = eng.pool.stats.allocs - allocs_before
    shared = r2.cached_prefix_len // eng.pool.page_size
    total = -(-len(r2.prompt_tokens) // eng.pool.page_size)
    assert fresh <= total - shared + 1, (
        f"COW admission allocated {fresh} fresh pages, expected at most "
        f"{total - shared + 1} (only past the divergence point)")
    assert eng.pool.stats.full_copies == 0
    assert eng.pool.stats.shares > 0

    # both outputs bit-identical to a dense engine (sharing changed memory
    # layout, never semantics)
    dense = InferenceEngine(cfg, max_batch=2, cache_len=128)
    d1 = _req(base + "tail one", max_tokens=6)
    d2 = _req(base + "tail TWO!", max_tokens=6)
    dense.generate([d1])
    dense.generate([d2])
    assert r1.output_tokens == d1.output_tokens
    assert r2.output_tokens == d2.output_tokens

    occ = eng.pool.page_occupancy()
    assert occ["pinned"] == 0
    assert occ["free"] + occ["reclaimable"] == occ["total"]


def test_page_pool_exhaustion_pressure_ladder(cfg):
    """A deliberately tiny arena forces the pressure ladder: cache leases
    are reclaimed first, and every request still finishes (nothing hangs,
    nothing corrupts — outputs stay bit-identical to dense)."""
    eng = InferenceEngine(cfg, max_batch=2, cache_len=128,
                          kv_layout="paged", kv_page_size=16,
                          kv_num_pages=2 + 2 * 8)    # reserved + exactly 2 slots
    reqs = [_req(f"request {i} " + "pad " * 12, max_tokens=8)
            for i in range(4)]
    paged = _outputs(eng, reqs)
    dense = InferenceEngine(cfg, max_batch=2, cache_len=128)
    ref = _outputs(dense, [_req(f"request {i} " + "pad " * 12, max_tokens=8)
                           for i in range(4)])
    assert paged == ref
    occ = eng.pool.page_occupancy()
    assert occ["pinned"] == 0


# --------------------------------------------------------------------------- #
# preemption / snapshot / resume under paging
# --------------------------------------------------------------------------- #
def _preempt_scenario(cfg, *, paged, policy="edf", preemption=True,
                      prefix_cache=True):
    kw = dict(kv_layout="paged", kv_page_size=16) if paged else {}
    eng = InferenceEngine(cfg, max_batch=1, cache_len=256,
                          sched_policy=policy, preemption=preemption,
                          enable_prefix_cache=prefix_cache, **kw)
    batch = _req("long-running batch request " * 2, max_tokens=24)
    eng.add_request(batch)
    for _ in range(4):
        eng.step()
    urgent = _req("urgent interactive!", max_tokens=6, deadline_ms=1.0)
    eng.add_request(urgent)
    eng.run()
    return batch, urgent, eng


@pytest.mark.parametrize("prefix_cache", [True, False])
def test_paged_preemption_resume_bit_identical(cfg, prefix_cache):
    """Eviction snapshots under paging are page-lease references (no dense
    copy); resume adopts the pages back.  Both the prefix-cache snapshot
    path and the engine-side fallback must keep the evictee bit-identical
    to an unpreempted FIFO run, and every lease must unwind (occupancy
    returns to free once both requests retire)."""
    b, u, eng = _preempt_scenario(cfg, paged=True, prefix_cache=prefix_cache)
    assert eng.scheduler.stats.preemptions >= 1
    assert eng.scheduler.stats.resumed >= 1
    ref_b, ref_u, _ = _preempt_scenario(cfg, paged=False, policy="fifo",
                                        preemption=False,
                                        prefix_cache=prefix_cache)
    assert b.output_tokens == ref_b.output_tokens
    assert u.output_tokens == ref_u.output_tokens
    occ = eng.pool.page_occupancy()
    assert occ["pinned"] == 0
    assert occ["free"] + occ["reclaimable"] == occ["total"]


# --------------------------------------------------------------------------- #
# pool-level unit coverage (no engine)
# --------------------------------------------------------------------------- #
def test_pool_insert_read_roundtrip_and_occupancy(cfg):
    pool = PagedKVPool(cfg, max_batch=2, cache_len=64, page_size=16)
    single = jax.tree.map(
        lambda a: jnp.asarray(np.random.default_rng(0).normal(
            size=a.shape).astype(a.dtype) if jnp.issubdtype(
                a.dtype, jnp.floating) else np.zeros(a.shape, a.dtype)),
        pool.single_cache_zeros())
    slot = pool.allocate()
    pool.insert_many([slot], [single], consumed=[40])   # 3 of 4 pages
    assert len(pool.slot_pages(slot)) == 3
    occ = pool.page_occupancy()
    assert occ["pinned"] == 3 and occ["free"] == occ["total"] - 3

    back = pool.read(slot)
    # written positions round-trip exactly; the never-allocated tail page
    # reads back as zeros (dense rows start from zeros)
    for i, sub in enumerate(back["prefix"]):
        if "k" not in sub:
            continue
        want = np.asarray(single["prefix"][i]["k"])
        got = np.asarray(sub["k"])
        np.testing.assert_array_equal(got[:, :48], want[:, :48])
        assert not got[:, 48:].any()

    pool.free(slot)
    occ = pool.page_occupancy()
    assert occ["pinned"] == 0 and occ["free"] == occ["total"]
    assert pool.stats.allocs == pool.stats.frees == 3


def test_pool_ensure_capacity_allocates_tail_and_splits_shared(cfg):
    pool = PagedKVPool(cfg, max_batch=2, cache_len=64, page_size=16)
    single = pool.single_cache_zeros()
    slot = pool.allocate()
    pool.insert_many([slot], [single], consumed=[16])    # one full page
    # decode at pos 16 crosses into page 1 -> lazy tail alloc
    assert pool.ensure_decode_capacity({slot: 16}, 4)
    assert len(pool.slot_pages(slot)) == 2

    # share page 0, then write into it -> COW split, sharer keeps the old id
    shared_page = pool.slot_pages(slot)[0]
    pool.incref_pages([shared_page])
    assert pool.ensure_decode_capacity({slot: 4}, 1)
    assert pool.stats.cow_splits == 1
    assert pool.slot_pages(slot)[0] != shared_page
    assert pool.allocator.refcount(shared_page) == 1     # lease survives
    pool.release_pages([shared_page])

    # exhaustion: returns False with no partial effects
    before = list(pool.slot_pages(slot))
    free_now = pool.allocator.num_free
    for _ in range(free_now):
        pool.allocator.alloc()                            # drain the arena
    assert not pool.ensure_decode_capacity({slot: 32}, 1)
    assert pool.slot_pages(slot) == before


# --------------------------------------------------------------------------- #
# kernel: interpret-mode pallas vs host reference
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("int8", [False, True])
def test_paged_attention_kernel_matches_reference(rng, int8):
    from repro.kernels.paged_attention import paged_attention_pallas

    b, hq, hkv, d, ps, pages_per_slot, npages = 3, 4, 2, 32, 8, 4, 16
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    kp = rng.normal(size=(npages, ps, hkv, d)).astype(np.float32)
    vp = rng.normal(size=(npages, ps, hkv, d)).astype(np.float32)
    pt = rng.integers(1, npages, size=(b, pages_per_slot)).astype(np.int32)
    pos = np.array([5, 17, 31], np.int32)
    ks = vs = None
    if int8:
        from repro.kernels.quant_matmul import quantize_kv_int8
        kp, ks = quantize_kv_int8(kp)
        vp, vs = quantize_kv_int8(vp)
        kp, vp = np.asarray(kp), np.asarray(vp)
        ks, vs = np.asarray(ks), np.asarray(vs)
    ref = paged_attention_ref(q, kp, vp, pt, pos, k_scale=ks, v_scale=vs)
    out = paged_attention_pallas(q, kp, vp, pt, pos, k_scale=ks, v_scale=vs,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_reference_matches_dense_at_full_page(rng):
    """ps == cache_len, identity table -> the paged reference IS dense
    attention (the analytical core of the bit-exactness gate)."""
    b, hq, hkv, d, s = 2, 4, 2, 16, 32
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    k = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    v = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    pos = np.array([7, 31], np.int32)
    kv_valid = np.arange(s)[None, :] <= pos[:, None]
    ref = decode_attention_ref(q, k, v, kv_valid)
    pt = np.arange(b, dtype=np.int32)[:, None]           # slot -> page slot
    out = paged_attention_ref(q, k, v, pt, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
