"""The §Perf sharding variants must (a) lower through the dry-run glue and
(b) compute the same mathematics as the baseline rules (the mesh is 1x1
here, so every layout is numerically identical by construction — what this
pins is that the variant *specs* are legal for every param/cache shape)."""
import jax
import pytest

from repro.configs import get_config
from repro.distributed import use_sharding
from repro.launch.specs import build_step_spec, shape_rules
import repro.launch.specs as specs_mod

TINY_SHAPES = {
    "train_4k": dict(seq=32, batch=4, kind="train"),
    "decode_32k": dict(seq=32, batch=2, kind="decode"),
}


@pytest.fixture
def tiny_shapes():
    saved = dict(specs_mod.SHAPES)
    specs_mod.SHAPES = dict(TINY_SHAPES)
    yield
    specs_mod.SHAPES = saved


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("moe_shard", ["fsdp", "2d", "ep"])
def test_moe_variants_lower_and_agree(tiny_shapes, moe_shard):
    cfg = get_config("grok-1-314b").reduced()
    mesh = _mesh11()
    rules = shape_rules(cfg, "train_4k", mesh, fsdp=True,
                        moe_shard=moe_shard)
    spec = build_step_spec(cfg, "train_4k")
    with use_sharding(mesh, rules):
        jitted = jax.jit(spec.fn,
                         in_shardings=spec.in_shardings(mesh, rules),
                         out_shardings=spec.out_shardings(mesh, rules),
                         donate_argnums=spec.donate_argnums)
        compiled = jitted.lower(*spec.args).compile()
    assert compiled.cost_analysis() is not None


@pytest.mark.parametrize("layout", ["dp", "2dtp"])
def test_decode_layouts_lower(tiny_shapes, layout):
    cfg = get_config("jamba-1.5-large-398b").reduced()
    mesh = _mesh11()
    rules = shape_rules(cfg, "decode_32k", mesh, fsdp=True, layout=layout,
                        moe_shard="2d" if layout == "2dtp" else "fsdp")
    spec = build_step_spec(cfg, "decode_32k")
    with use_sharding(mesh, rules):
        jitted = jax.jit(spec.fn,
                         in_shardings=spec.in_shardings(mesh, rules),
                         out_shardings=spec.out_shardings(mesh, rules),
                         donate_argnums=spec.donate_argnums)
        compiled = jitted.lower(*spec.args).compile()
    assert compiled.cost_analysis() is not None


def test_microbatched_spec_lowers(tiny_shapes):
    cfg = get_config("qwen2-0.5b").reduced()
    mesh = _mesh11()
    rules = shape_rules(cfg, "train_4k", mesh, fsdp=False)
    spec = build_step_spec(cfg, "train_4k", microbatches=2,
                           microbatch_unroll=True)
    with use_sharding(mesh, rules):
        compiled = jax.jit(
            spec.fn, in_shardings=spec.in_shardings(mesh, rules),
            out_shardings=spec.out_shardings(mesh, rules),
            donate_argnums=spec.donate_argnums).lower(*spec.args).compile()
    assert compiled.cost_analysis() is not None
