"""Chunked, batched, decode-overlapped prefill pipeline.

Pins the admission-path contract: chunked + batched prefill is *bit
-identical* to monolithic prefill (final KV cache, published prefix-cache
blocks, greedy decode outputs), mid-chunk prefix publication is reusable,
wave packing preserves per-request outputs, the compiled bucket set stays
bounded, and the new observability surfaces (scheduler queue stats, /stats
endpoint, prefill_overlap smoke benchmark) work."""
import json
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import InferenceEngine
from repro.core.request import Request, SamplingParams
from repro.core.scheduler import ContinuousBatchingScheduler
from repro.serving.tokenizer import ByteTokenizer

TOK = ByteTokenizer()
LONG = "shared system prompt for equivalence checking " * 3   # ~139 tokens


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3-0.6b-toy")


def _req(text, max_tokens=6):
    return Request(prompt_tokens=TOK.encode(text),
                   sampling=SamplingParams(max_tokens=max_tokens))


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# --------------------------------------------------------------------------- #
# bit-exact equivalence: chunked vs monolithic
# --------------------------------------------------------------------------- #
def test_chunked_prefill_bit_identical_to_monolithic(cfg):
    """Greedy outputs AND the published full-prompt prefix-cache entry must
    be bit-identical across prefill_chunk ∈ {0 (monolithic), pow2, non-pow2}
    — right-padding is fully masked, so chunk geometry leaves no trace."""
    toks = TOK.encode(LONG)
    outs, entries = [], []
    for chunk in (0, 32, 48):
        eng = InferenceEngine(cfg, max_batch=1, cache_len=256,
                              prefill_chunk=chunk, prefix_block_size=8)
        r = Request(prompt_tokens=list(toks),
                    sampling=SamplingParams(max_tokens=4))
        eng.generate([r])
        outs.append(r.output_tokens)
        value, matched = eng.prefix_cache.lookup(list(toks),
                                                 max_len=len(toks))
        assert value is not None and matched > 0
        entries.append(value["cache"])
    assert outs[0] == outs[1] == outs[2]
    assert _leaves_equal(entries[0], entries[1])
    assert _leaves_equal(entries[0], entries[2])


def test_chunked_run_publishes_partial_prefixes(cfg):
    """Intermediate chunk boundaries publish (rolling) to the prefix cache:
    an identical prompt arriving while the first is still mid-prefill
    resumes from the finished chunks — and decodes identically to an engine
    with no prefix cache at all."""
    eng = InferenceEngine(cfg, max_batch=2, cache_len=256,
                          prefill_chunk=32, prefix_block_size=8)
    a = _req(LONG)
    eng.add_request(a)
    for _ in range(3):                     # 3 chunks = 96 prompt tokens done
        eng.step()
    b = _req(LONG)                         # identical prompt, mid-prefill
    eng.add_request(b)
    eng.run()
    assert a.is_finished and b.is_finished
    # b resumed from a's latest published chunk boundary, not from scratch
    assert b.cached_prefix_len >= 64
    assert a.output_tokens == b.output_tokens
    # rolling publication: one partial + the retire-time full entry — NOT
    # one full-size cache per chunk boundary
    assert len(eng.prefix_cache) <= 3

    ref_eng = InferenceEngine(cfg, max_batch=2, cache_len=256,
                              prefill_chunk=32, enable_prefix_cache=False)
    c = _req(LONG)
    ref_eng.generate([c])
    assert b.output_tokens == c.output_tokens


def test_prefix_hit_mid_prompt_with_chunked_resume(cfg):
    """A cached prefix consumed *mid-chunk*: the resume offset lands inside
    the chunk grid and the remaining tokens still chunk correctly."""
    base = "common prefix tokens here " * 6                   # > 2 chunks
    outs = []
    for chunk in (0, 32):
        eng = InferenceEngine(cfg, max_batch=2, cache_len=256,
                              prefill_chunk=chunk, prefix_block_size=8)
        # short suffixes: the published entry's block-aligned key must land
        # inside the shared prefix for the second prompt to hit it
        eng.generate([_req(base + "AA", 4)])
        b = _req(base + "BB", 4)
        eng.generate([b])
        assert b.cached_prefix_len > 0
        outs.append(b.output_tokens)
    assert outs[0] == outs[1]


# --------------------------------------------------------------------------- #
# batched waves
# --------------------------------------------------------------------------- #
def test_batched_wave_equals_sequential(cfg):
    """One [k, bucket] wave (staggered lengths, per-row masks/offsets) must
    reproduce per-request batch=1 prefills token for token."""
    def reqs():
        return [_req(p, m) for p, m in
                [("a", 3), ("bb word", 9), (LONG, 8), ("mid size", 6),
                 ("x" * 40, 12)]]

    seq = InferenceEngine(cfg, max_batch=1, cache_len=256,
                          enable_prefix_cache=False, prefill_chunk=0)
    bat = InferenceEngine(cfg, max_batch=4, cache_len=256,
                          enable_prefix_cache=False, prefill_chunk=32)
    for ra, rb in zip(seq.generate(reqs()), bat.generate(reqs())):
        assert ra.output_tokens == rb.output_tokens
        assert ra.finish_reason == rb.finish_reason
    # the batched engine actually packed rows (admission wave of 4)
    assert bat.scheduler.stats.rows_per_wave > 1.0


def test_legacy_admission_path_is_gone(cfg):
    """The deprecated pre-pipeline baseline was removed (ROADMAP removal
    target after PR 3 baselined it): the knob must not silently no-op."""
    with pytest.raises(TypeError):
        InferenceEngine(cfg, max_batch=1, cache_len=64,
                        legacy_admission=True)


def test_vision_chunked_wave_equivalence():
    """Multimodal rows ride the wave: media context + cross-KV publication
    happen on the first chunk; outputs are invariant to chunking."""
    vcfg = get_config("qwen3-vl-toy")
    img = np.random.default_rng(0).integers(0, 255, (32, 32, 3),
                                            dtype=np.uint8)
    outs = []
    for chunk in (0, 32):
        eng = InferenceEngine(vcfg, max_batch=2, cache_len=256,
                              vision_work_iters=2, prefill_chunk=chunk)
        r = Request(prompt_tokens=TOK.encode(LONG), images=[img],
                    sampling=SamplingParams(max_tokens=4))
        eng.generate([r])
        outs.append(r.output_tokens)
    assert outs[0] == outs[1]


def test_non_pow2_cache_len_no_scatter_collision(cfg):
    """cache_len=192 with a prompt whose pow2 bucket (256) would exceed the
    ring: the bucket must clamp so padding never aliases real prompt cells
    in one scatter.  Outputs must match a roomy-cache engine exactly."""
    prompt = TOK.encode(LONG)                  # 139 tokens -> pow2 bucket 256
    outs = []
    for cache_len, chunk in ((192, 0), (192, 32), (512, 0)):
        eng = InferenceEngine(cfg, max_batch=1, cache_len=cache_len,
                              prefill_chunk=chunk,
                              enable_prefix_cache=False)
        r = Request(prompt_tokens=list(prompt),
                    sampling=SamplingParams(max_tokens=6))
        eng.generate([r])
        outs.append(r.output_tokens)
    assert outs[0] == outs[1] == outs[2]


# --------------------------------------------------------------------------- #
# bucket capping
# --------------------------------------------------------------------------- #
def test_bucket_cap_bounds_compiled_shapes(cfg, caplog):
    """max_prefill_buckets raises the bucket floor so varied prompt lengths
    reuse a small fixed set of compiled shapes (warned on first compile)."""
    import logging
    eng = InferenceEngine(cfg, max_batch=2, cache_len=256,
                          enable_prefix_cache=False, max_prefill_buckets=2)
    with caplog.at_level(logging.WARNING, logger="repro.engine"):
        eng.generate([_req("t" * n, 2) for n in (3, 20, 60, 130, 200)])
    assert len(eng._seen_buckets) <= 2
    assert all(b in (128, 256) for b in eng._seen_buckets)
    assert any("prefill bucket" in rec.message for rec in caplog.records)


# --------------------------------------------------------------------------- #
# scheduler interleave + observability
# --------------------------------------------------------------------------- #
def test_plan_decode_block_collapses_while_chunks_pending():
    s = ContinuousBatchingScheduler(max_batch=2)
    r = _req("active one", 100)
    s.add(r)
    s.admit([0])
    assert s.plan_decode_block(8) == 8
    s.enqueue_prefill(object())          # opaque chunk job
    assert s.plan_decode_block(8) == 1   # TTFT-aware interleave
    assert s.has_work
    s.pop_prefill_wave()
    assert s.plan_decode_block(8) == 8


def test_queue_depth_and_oldest_wait_exposed():
    s = ContinuousBatchingScheduler(max_batch=1)
    assert s.queue_depth == 0 and s.oldest_wait_s == 0.0
    s.add(_req("waiting", 2))
    s.add(_req("waiting more", 2))
    assert s.queue_depth == 2
    assert s.oldest_wait_s >= 0.0
    snap = s.snapshot()
    for key in ("queue_depth", "oldest_wait_s", "prefill_waves",
                "prefill_chunks", "rows_per_wave", "host_syncs_per_token"):
        assert key in snap


def test_stats_endpoint_serves_scheduler_snapshot(cfg):
    from repro.serving.api import OpenAIServer
    from repro.serving.server import ApiServer

    eng = InferenceEngine(cfg, max_batch=2, cache_len=128)
    api = OpenAIServer(eng, "toy")
    st = api.stats()
    assert st["queue_depth"] == 0
    assert st["prefill_chunk"] == eng.prefill_chunk
    assert "prefix_cache" in st

    server = ApiServer(api, port=0)
    server.start()
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{server.port}/stats", timeout=30) as resp:
            body = json.loads(resp.read())
        assert body["queue_depth"] == 0
        assert body["max_batch"] == 2
        assert "oldest_wait_s" in body
    finally:
        server.stop()


# --------------------------------------------------------------------------- #
# benchmark smoke (tier-1 regression gate for the admission path)
# --------------------------------------------------------------------------- #
def test_prefill_overlap_benchmark_smoke(tmp_path):
    from benchmarks import prefill_overlap

    out = tmp_path / "BENCH_prefill_overlap.json"
    result = prefill_overlap.run(smoke=True, out=out)
    assert out.exists()
    rows = result["rows"]
    variants = {(r["variant"], r["chunk"]) for r in rows}
    assert ("pipeline", 0) in variants and len(variants) >= 2
    assert all(v == "pipeline" for v, _ in variants)   # pre_pr is gone
    for r in rows:
        assert r["tok_s"] > 0
        assert r["ttft_p95_ms"] >= r["ttft_p50_ms"] >= 0
