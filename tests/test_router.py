"""Multi-replica router: placement, affinity, drain/handoff, stats v2.

Pins the PR 10 contract (DESIGN_router.md): the router fronts N
in-process engine replicas with prefix-cache-aware placement and session
affinity; draining a replica hands its live slots to a successor that
resumes them *bit-identically* through the exact-sequence snapshot path;
``n>1`` fan-out admits as one shared-prefix group with zero full-cache
copies under the paged layout; and ``GET /stats`` serves the versioned
``router`` / ``replicas[]`` envelope with the flat legacy keys mirrored
one release."""
import time

import pytest

from repro.configs import get_config
from repro.core.admission import AdmissionController, Overloaded, RateLimited
from repro.core.admission import TenantConfig
from repro.core.engine import InferenceEngine
from repro.core.request import GenerationRequest, SamplingParams
from repro.serving.api import OpenAIServer
from repro.serving.client import EngineClient
from repro.serving.router import (ReplicaStats, Router, RouterStats,
                                  _digest_chain)

LONG = "a shared system prompt that spans multiple digest blocks " * 3


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3-0.6b-toy")


def mk_client(cfg, *, admission=True, seed=0, layout="dense", **adm_kw):
    eng = InferenceEngine(cfg, max_batch=4, cache_len=256, seed=seed,
                          kv_layout=layout, kv_page_size=16)
    adm = AdmissionController(**adm_kw) if admission else None
    return EngineClient(eng, admission=adm)


def greq(prompt, max_tokens=4, **kw):
    return GenerationRequest(prompt=prompt,
                             sampling=SamplingParams(max_tokens=max_tokens),
                             **kw)


# --------------------------------------------------------------------- #
# shared-prefix n>1 groups (the PR 7 carried-forward API item)
# --------------------------------------------------------------------- #
def test_n_fanout_shares_prefix_with_zero_full_copies(cfg):
    """n=4 admits as one group: one prefill, three shared admissions,
    zero full-cache copies — and the choices match an independent n=1
    run bit-for-bit (greedy).  Prefix cache OFF: sharing comes from the
    engine-owned group table, not the cache."""
    eng = InferenceEngine(cfg, max_batch=8, cache_len=256, seed=0,
                          kv_layout="paged", kv_page_size=16,
                          enable_prefix_cache=False)
    with EngineClient(eng) as client:
        res = client.submit(greq(LONG, max_tokens=8, n=4)).result(timeout=120)
        texts = [c.text for c in res.choices]
    assert len(texts) == 4 and len(set(texts)) == 1
    assert eng.group_stats["groups"] == 1
    assert eng.group_stats["shared_admits"] == 3
    assert eng.pool.stats.full_copies == 0

    eng2 = InferenceEngine(cfg, max_batch=8, cache_len=256, seed=0,
                           kv_layout="paged", kv_page_size=16,
                           enable_prefix_cache=False)
    with EngineClient(eng2) as solo:
        ref = solo.submit(greq(LONG, max_tokens=8, n=1)).result(timeout=120)
    assert texts[0] == ref.choices[0].text


def test_n_fanout_group_dense_layout(cfg):
    """Dense layout shares through the snapshot row instead of COW pages;
    outputs still identical across choices."""
    eng = InferenceEngine(cfg, max_batch=8, cache_len=256, seed=0,
                          enable_prefix_cache=False)
    with EngineClient(eng) as client:
        res = client.submit(greq(LONG, max_tokens=6, n=3)).result(timeout=120)
        texts = [c.text for c in res.choices]
    assert len(set(texts)) == 1
    assert eng.group_stats["shared_admits"] == 2


# --------------------------------------------------------------------- #
# placement
# --------------------------------------------------------------------- #
def test_session_affinity_pins_to_one_replica(cfg):
    with Router([mk_client(cfg), mk_client(cfg)]) as router:
        for turn in range(3):
            h = router.submit(greq(f"turn {turn} of the conversation",
                                   session="chat-1"))
            h.result(timeout=120)
        stats = router.router_stats()
        assert isinstance(stats, RouterStats)
        # first turn placed by load, later turns by session pin
        assert stats.placements["session"] == 2
        assert stats.sessions_pinned == 1


def test_prefix_affinity_routes_to_warm_replica(cfg):
    """A second request sharing a long prompt prefix lands on the replica
    that served the first (the router-side digest index), regardless of
    load order."""
    with Router([mk_client(cfg), mk_client(cfg)]) as router:
        router.submit(greq(LONG + " question one")).result(timeout=120)
        first = next(i for i, r in enumerate(router.replicas) if r.submitted)
        router.submit(greq(LONG + " question two")).result(timeout=120)
        stats = router.router_stats()
        assert stats.placements["prefix"] == 1
        # both requests on the same replica
        assert router.replicas[first].submitted == 2


def test_digest_chain_properties():
    a = _digest_chain(LONG + "suffix one")
    b = _digest_chain(LONG + "suffix two")
    c = _digest_chain("completely different prompt " * 4)
    shared = sum(1 for x, y in zip(a, b) if x == y)
    assert shared >= 1                      # long shared prefix matches
    assert a[:shared] == b[:shared]         # chain => prefix property
    assert not set(a) & set(c)              # disjoint prompts, no overlap
    # token prompts hash too (pre-tokenised API path)
    assert _digest_chain(list(range(64))) != _digest_chain(list(range(64, 128)))


def test_round_robin_and_random_policies(cfg):
    with Router([mk_client(cfg), mk_client(cfg)],
                policy="round_robin") as router:
        for i in range(4):
            router.submit(greq(f"rr {i}", max_tokens=2)).result(timeout=120)
        assert router.router_stats().placements["round_robin"] == 4
        # both replicas saw traffic
        assert all(r.submitted > 0 for r in router.replicas)
    with Router([mk_client(cfg), mk_client(cfg)], policy="random",
                seed=7) as router:
        for i in range(4):
            router.submit(greq(f"rnd {i}", max_tokens=2)).result(timeout=120)
        assert router.router_stats().placements["random"] == 4


def test_shed_bulk_replica_stops_taking_batch_traffic(cfg):
    """Degradation-ladder awareness: a replica stuck at SHED_BULK
    (shed_queue_depth=0 makes the ladder trip immediately) receives no
    batch-class requests while a healthy replica exists."""
    shedding = mk_client(cfg, shed_queue_depth=0, shed_wait_s=0)
    healthy = mk_client(cfg)
    with Router([shedding, healthy]) as router:
        assert router.replicas[0].sheds_batch()
        for i in range(3):
            router.submit(greq(f"batch job {i}", max_tokens=2)).result(timeout=120)
        assert router.replicas[0].submitted == 0
        assert router.replicas[1].submitted == 3


def test_rate_limited_propagates_without_failover(cfg):
    """Tenant budget rejection is policy, not replica fault: the router
    must not retry it on another replica (double-spending the budget)."""
    limited = mk_client(cfg, tenants={"t1": TenantConfig(
        weight=1, rps=0.001, burst_requests=1.0)})
    with Router([limited, mk_client(cfg)]) as router:
        router.submit(greq("first", max_tokens=2, tenant="t1",
                           session="pin")).result(timeout=120)
        with pytest.raises(RateLimited):
            router.submit(greq("second", max_tokens=2, tenant="t1",
                               session="pin"))
        assert router.router_stats().failovers == 0


def test_failover_on_refusing_replica(cfg):
    """A replica that refuses a submit (its admission entered drain
    before the router noticed — the rolling-restart race) is failed over,
    not surfaced to the caller.  Priority traffic bypasses the router's
    SHED_BULK filter, so placement genuinely hits the refusing replica."""
    a, b = mk_client(cfg), mk_client(cfg)
    with Router([a, b], policy="round_robin") as router:
        a._admission.start_drain()
        for i in range(4):
            router.submit(greq(f"after refusal {i}", max_tokens=2,
                               priority=1)).result(timeout=120)
        assert router.router_stats().failovers >= 1
        assert router.replicas[0].submitted == 0
        assert router.replicas[1].submitted == 4


def test_all_draining_rejects_with_structured_503(cfg):
    with Router([mk_client(cfg), mk_client(cfg)]) as router:
        for rep in router.replicas:
            rep.client._draining = True
        with pytest.raises(Overloaded) as ei:
            router.submit(greq("too late"))
        assert ei.value.code == "draining"
        assert ei.value.status == 503
        assert ei.value.retry_after > 0


# --------------------------------------------------------------------- #
# drain / handoff
# --------------------------------------------------------------------- #
def _outputs(handles):
    return [tuple(h._requests[0].output_tokens)
            for h in handles if h.result(timeout=120)]


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_drain_handoff_bit_identity(cfg, layout):
    """Mid-decode drain: live slots hand off as exact cache snapshots and
    the successor's continuations match an undrained single-replica run
    token for token."""
    prompts = [f"handoff identity prompt {i} with several words" for i in range(3)]

    ref = mk_client(cfg, admission=False, layout=layout)
    with ref:
        refs = [ref.submit(greq(p, max_tokens=24)) for p in prompts]
        expected = _outputs(refs)

    a = mk_client(cfg, admission=False, layout=layout)
    b = mk_client(cfg, admission=False, layout=layout)
    with Router([a, b], policy="round_robin") as router:
        # pin all three to replica a so the drain moves live decode slots
        handles = [a.submit(greq(p, max_tokens=24)) for p in prompts]
        for rep, client in zip(router.replicas, (a, b)):
            if client is a:
                rep.open = [(h, 1000) for h in handles]
        time.sleep(2.0)
        info = router.drain_replica(0)
        assert info["adopted"] == info["exported"] > 0
        assert _outputs(handles) == expected
        assert router.replicas[0].state == "stopped"
        assert router.router_stats().handoffs == 1


def test_session_affinity_survives_drain(cfg):
    """A pinned session keeps streaming through its replica's drain: the
    in-flight turn migrates with the handoff and the *next* turn follows
    the re-pin to the successor."""
    with Router([mk_client(cfg), mk_client(cfg)]) as router:
        h = router.submit(greq("long running turn with words",
                               max_tokens=32, session="sticky"))
        time.sleep(1.5)
        pinned = router._sessions["sticky"]
        router.drain_replica(pinned)
        assert h.result(timeout=120).choices[0].finish_reason in ("length", "stop")
        next_turn = router.submit(greq("the next turn", max_tokens=2,
                                       session="sticky"))
        next_turn.result(timeout=120)
        assert router._sessions["sticky"] != pinned
        assert router.router_stats().placements["session"] >= 1


def test_drain_replica_rejects_bad_successor(cfg):
    with Router([mk_client(cfg), mk_client(cfg)]) as router:
        with pytest.raises(ValueError):
            router.drain_replica(0, successor=0)
        assert router.replicas[0].state == "up"     # rolled back
        router.replicas[1].client.stop()
        with pytest.raises(RuntimeError):
            router.drain_replica(0)                 # no successor available
        assert router.replicas[0].state == "up"


# --------------------------------------------------------------------- #
# stats v2 envelope
# --------------------------------------------------------------------- #
def test_stats_v2_envelope_and_typed_accessors(cfg):
    with Router([mk_client(cfg), mk_client(cfg)]) as router:
        router.submit(greq("warm up", max_tokens=2)).result(timeout=120)
        api = OpenAIServer(router, "toy")
        out = api.stats()
        assert out["schema_version"] == OpenAIServer.STATS_SCHEMA_VERSION
        assert out["router"]["policy"] == "affinity"
        assert len(out["replicas"]) == 2
        names = [r["name"] for r in out["replicas"]]
        assert names == ["replica-0", "replica-1"]
        # legacy flat keys still mirrored (one release), with the notice
        assert "max_batch" in out and "retired" in out
        assert "deprecation" in out
        # typed accessors
        for rs in router.replica_stats():
            assert isinstance(rs, ReplicaStats)
            assert rs.state == "up" and rs.alive
        assert isinstance(router.router_stats(), RouterStats)


def test_stats_v2_single_replica_shape(cfg):
    """Without a router the envelope still carries replicas[] (length 1)
    and router: None, plus the untouched flat keys."""
    with mk_client(cfg) as client:
        api = OpenAIServer(client, "toy")
        out = api.stats()
        assert out["schema_version"] == 2
        assert out["router"] is None
        assert len(out["replicas"]) == 1
        assert out["replicas"][0]["name"] == "replica-0"
        assert "max_batch" in out


def test_router_health_surface(cfg):
    a, b = mk_client(cfg), mk_client(cfg)
    with Router([a, b]) as router:
        assert router.alive and router.ready and not router.draining
        assert router.engine is a.engine
        assert router._admission is a._admission
        a.stop()
        assert router.alive and router.ready       # b still up
        b._draining = True
        assert not router.ready
