"""Deadline-aware scheduling: policy ordering, speculative wave filling,
slot preemption, per-class latency observability, and the sched_policy
benchmark smoke.

Pins the policy-subsystem contract: policies only reorder *schedule*, never
semantics — greedy outputs stay bit-identical to the non-preempting FIFO
path for every request, including evicted-and-resumed ones; speculative
filling changes wave packing only; per-class latency surfaces in
``GET /stats`` and stays snapshot-consistent under concurrent readers."""
import json
import threading
import urllib.request

import pytest

from repro.configs import get_config
from repro.core.engine import InferenceEngine
from repro.core.request import Request, SamplingParams
from repro.core.scheduler import (ContinuousBatchingScheduler, EDFPolicy,
                                  FIFOPolicy, PriorityPolicy, make_policy)
from repro.serving.tokenizer import ByteTokenizer

TOK = ByteTokenizer()
LONG = "shared system prompt for equivalence checking " * 3   # ~139 tokens


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3-0.6b-toy")


def _req(text, max_tokens=6, priority=0, deadline_ms=None):
    return Request(prompt_tokens=TOK.encode(text),
                   sampling=SamplingParams(max_tokens=max_tokens),
                   priority=priority, deadline_ms=deadline_ms)


# --------------------------------------------------------------------------- #
# policy ordering (pure scheduler)
# --------------------------------------------------------------------------- #
def test_make_policy_resolves_names_and_rejects_unknown():
    assert isinstance(make_policy("fifo"), FIFOPolicy)
    assert isinstance(make_policy("priority"), PriorityPolicy)
    assert isinstance(make_policy("edf"), EDFPolicy)
    assert isinstance(make_policy(None), FIFOPolicy)
    with pytest.raises(ValueError):
        make_policy("shortest-job-first")


def test_priority_policy_orders_admission():
    s = ContinuousBatchingScheduler(max_batch=2, policy="priority")
    low, high, mid = _req("low"), _req("high", priority=9), \
        _req("mid", priority=4)
    for r in (low, high, mid):
        s.add(r)
    admitted = s.admit([0, 1])
    assert [r.request_id for _, r in admitted] == [high.request_id,
                                                  mid.request_id]
    assert s.pending == [low]


def test_edf_policy_orders_by_deadline_then_fifo():
    s = ContinuousBatchingScheduler(max_batch=3, policy="edf")
    none1 = _req("no deadline, first")
    tight = _req("tight", deadline_ms=10.0)
    loose = _req("loose", deadline_ms=10_000.0)
    for r in (none1, loose, tight):
        s.add(r)
    admitted = s.admit([0, 1, 2])
    assert [r.request_id for _, r in admitted] == [
        tight.request_id, loose.request_id, none1.request_id]


def test_chunk_queue_drains_in_policy_order():
    class Job:
        def __init__(self, req):
            self.req = req

    s = ContinuousBatchingScheduler(max_batch=4, policy="edf")
    a, b, c = (_req("a"), _req("b", deadline_ms=5.0),
               _req("c", deadline_ms=50.0))
    for r in (a, b, c):
        s.enqueue_prefill(Job(r))
    wave = s.pop_prefill_wave()
    assert [j.req.request_id for j in wave] == [b.request_id, c.request_id,
                                                a.request_id]
    # opaque payloads (no .req) keep FIFO order ahead of request jobs
    s.enqueue_prefill(object())
    s.enqueue_prefill(Job(b))
    wave = s.pop_prefill_wave()
    assert not hasattr(wave[0], "req") and wave[1].req is b


def test_fifo_policy_is_default_and_never_preemptive():
    s = ContinuousBatchingScheduler(max_batch=1)
    assert s.policy.name == "fifo" and not s.policy.preemptive
    assert make_policy("priority").preemptive
    assert make_policy("edf").preemptive


def test_select_victim_and_requeue():
    s = ContinuousBatchingScheduler(max_batch=2, policy="edf")
    soon, late = _req("soon", deadline_ms=5.0), _req("late")
    s.add(soon)
    s.add(late)
    s.admit([0, 1])
    slot, victim = s.select_victim({0, 1}, max_preemptions=2)
    assert victim is late
    req = s.requeue(slot)
    assert req is late and req.preempt_count == 1
    assert s.num_active == 1 and s.pending == [late]
    assert s.stats.preemptions == 1
    # a maxed-out request is no longer an eligible victim
    late.preempt_count = 2
    s.admit([slot])
    assert s.select_victim({0, 1}, max_preemptions=2)[1] is soon


# --------------------------------------------------------------------------- #
# preemption: bit-identical greedy outputs vs the non-preempting FIFO path
# --------------------------------------------------------------------------- #
def _preempt_scenario(cfg, *, policy, preemption, prefix_cache,
                      cache_max_bytes=512 * 1024 * 1024):
    """One long batch request decodes alone; an urgent deadline request
    arrives with all slots busy."""
    eng = InferenceEngine(cfg, max_batch=1, cache_len=256,
                          sched_policy=policy, preemption=preemption,
                          enable_prefix_cache=prefix_cache,
                          cache_max_bytes=cache_max_bytes)
    batch = _req("long-running batch request " * 2, max_tokens=24)
    eng.add_request(batch)
    for _ in range(4):                   # commit + a few decode blocks
        eng.step()
    urgent = _req("urgent interactive!", max_tokens=6, deadline_ms=1.0)
    eng.add_request(urgent)
    eng.run()
    return batch, urgent, eng


def test_preemption_outputs_bit_identical_to_fifo(cfg):
    b1, u1, _ = _preempt_scenario(cfg, policy="fifo", preemption=False,
                                  prefix_cache=True)
    b2, u2, eng = _preempt_scenario(cfg, policy="edf", preemption=True,
                                    prefix_cache=True)
    assert eng.scheduler.stats.preemptions >= 1
    assert eng.scheduler.stats.resumed >= 1
    # the urgent request actually jumped the line...
    assert u2.finish_time < b2.finish_time
    # ...and nobody's greedy output changed — including the evictee, whose
    # decode resumed bit-for-bit from its snapshot
    assert b1.output_tokens == b2.output_tokens
    assert u1.output_tokens == u2.output_tokens
    assert b2.finish_reason == b1.finish_reason


def test_preemption_without_prefix_cache_uses_engine_side_snapshot(cfg):
    b, u, eng = _preempt_scenario(cfg, policy="edf", preemption=True,
                                  prefix_cache=False)
    assert eng.scheduler.stats.preemptions >= 1
    assert eng.scheduler.stats.resumed >= 1
    assert b.is_finished and u.is_finished
    ref, uref, _ = _preempt_scenario(cfg, policy="fifo", preemption=False,
                                     prefix_cache=False)
    assert b.output_tokens == ref.output_tokens
    assert u.output_tokens == uref.output_tokens


def test_preemption_resume_after_snapshot_lru_eviction(cfg):
    """A snapshot squeezed out of the byte-budget LRU degrades to the
    re-prefill resume path — outputs must still match FIFO exactly under
    monolithic re-prefill numerics (same prefill kernels, same positions)."""
    b, u, eng = _preempt_scenario(cfg, policy="edf", preemption=True,
                                  prefix_cache=True, cache_max_bytes=1)
    assert eng.scheduler.stats.preemptions >= 1
    assert eng.scheduler.stats.resumed == 0      # snapshot was LRU-evicted
    assert b.is_finished and u.is_finished
    assert b.num_generated == 24 and u.num_generated == 6
    ref_b, ref_u, _ = _preempt_scenario(cfg, policy="fifo", preemption=False,
                                        prefix_cache=True)
    assert b.output_tokens == ref_b.output_tokens
    assert u.output_tokens == ref_u.output_tokens


def test_engine_side_snapshots_bounded_by_pool_size(cfg):
    """Without a prefix cache there is no byte-budget LRU to own eviction
    snapshots, so the engine keeps at most one pool's worth (max_batch);
    older evictees degrade to the re-prefill resume path instead of
    pinning KV pytrees proportional to queue depth."""
    eng = InferenceEngine(cfg, max_batch=1, cache_len=256,
                          sched_policy="edf", preemption=True,
                          enable_prefix_cache=False)
    a = _req("batch request with no deadline " * 2, max_tokens=20)
    eng.add_request(a)
    for _ in range(3):
        eng.step()
    # deadline inside the EDF aging horizon (60s): it must sort ahead of
    # a's virtual deadline (arrival + horizon) for the eviction to happen
    b = _req("soonish deadline", max_tokens=12, deadline_ms=30_000.0)
    eng.add_request(b)
    eng.step()                               # b evicts a
    c = _req("urgent now", max_tokens=4, deadline_ms=1.0)
    eng.add_request(c)
    for _ in range(3):                       # c evicts b
        eng.step()
    assert eng.scheduler.stats.preemptions == 2
    held = [m for m in eng._evicted.values() if m["cache"] is not None]
    assert len(held) <= eng.pool.max_batch   # oldest snapshot was dropped
    eng.run()
    assert a.is_finished and b.is_finished and c.is_finished
    # b resumed from its kept snapshot; a fell back to re-prefill
    assert eng.scheduler.stats.resumed == 1


def test_seeded_sampling_replays_across_preemption(cfg):
    """A *stochastic* seeded request evicted mid-decode resumes its exact
    sampled stream: the per-token key is fold_in(base, position), so the
    restored snapshot (positions included) reproduces the draw chain — no
    split-chain state to lose with the slot."""
    def scenario(policy, preemption):
        eng = InferenceEngine(cfg, max_batch=1, cache_len=256,
                              sched_policy=policy, preemption=preemption)
        batch = Request(prompt_tokens=TOK.encode("long seeded batch " * 2),
                        sampling=SamplingParams(max_tokens=24,
                                                temperature=0.9, top_p=0.9,
                                                seed=1234))
        eng.add_request(batch)
        for _ in range(4):
            eng.step()
        urgent = _req("urgent interactive!", max_tokens=6, deadline_ms=1.0)
        eng.add_request(urgent)
        eng.run()
        return batch, urgent, eng

    b1, u1, _ = scenario("fifo", False)
    b2, u2, eng = scenario("edf", True)
    assert eng.scheduler.stats.preemptions >= 1
    assert eng.scheduler.stats.resumed >= 1
    assert len(set(b1.output_tokens)) > 1      # actually stochastic
    assert b1.output_tokens == b2.output_tokens
    assert u1.output_tokens == u2.output_tokens


def test_fifo_never_preempts_even_when_enabled(cfg):
    b, u, eng = _preempt_scenario(cfg, policy="fifo", preemption=True,
                                  prefix_cache=True)
    assert eng.scheduler.stats.preemptions == 0
    assert b.is_finished and u.is_finished


def test_no_preemption_of_ring_wrapped_slots(cfg):
    """A slot whose prompt+generated history fills the KV ring is not an
    eligible victim: if its snapshot were later lost, the re-prefill
    fallback could not rebuild a wrapped history exactly."""
    eng = InferenceEngine(cfg, max_batch=1, cache_len=64, sched_policy="edf",
                          preemption=True)
    hog = _req("x" * 40, max_tokens=60)      # 40 prompt + 60 gen >> 64 ring
    eng.add_request(hog)
    for _ in range(6):                       # decode well past cache_len
        eng.step()
    urgent = _req("now!", max_tokens=2, deadline_ms=1.0)
    eng.add_request(urgent)
    eng.run()
    assert eng.scheduler.stats.preemptions == 0
    assert hog.is_finished and urgent.is_finished


# --------------------------------------------------------------------------- #
# speculative wave filling
# --------------------------------------------------------------------------- #
def _spec_reqs():
    # staggered lengths keep wave sizes off powers of two -> padding rows
    return [_req(LONG[: 40 + 25 * i] + f" tail {i}", max_tokens=5)
            for i in range(5)]


def test_speculative_fill_outputs_identical_and_counters(cfg):
    mk = lambda spec: InferenceEngine(
        cfg, max_batch=3, cache_len=256, prefill_chunk=32,
        enable_prefix_cache=False, speculative_fill=spec)
    plain = mk(False).generate(_spec_reqs())
    eng = mk(True)
    spec = eng.generate(_spec_reqs())
    for ra, rb in zip(plain, spec):
        assert ra.output_tokens == rb.output_tokens
        assert ra.finish_reason == rb.finish_reason
    s = eng.scheduler.stats
    assert s.spec_jobs > 0 and s.spec_chunks > 0
    # at least one admission arrived with its prefill already in flight
    assert s.spec_admitted > 0


def test_speculative_fill_publishes_partial_prefixes(cfg):
    """A speculated request's chunks land in the prefix cache even before
    it is admitted — the head start is durable work, not a side buffer.
    Three staggered chunked prefills keep wave sizes at k=3 (kp=4), so one
    padding row per wave is available for the pending request."""
    eng = InferenceEngine(cfg, max_batch=3, cache_len=256, prefill_chunk=32,
                          prefix_block_size=8)
    hogs = [_req("slot hog " * (8 + 4 * i), max_tokens=24) for i in range(3)]
    for hog in hogs:
        eng.add_request(hog)
    eng.step()                            # hogs take all three slots
    waiting = _req(LONG, max_tokens=4)
    eng.add_request(waiting)
    for _ in range(6):                    # hogs chunk/decode; waiting rides
        eng.step()
    assert eng.scheduler.stats.spec_chunks > 0
    probe, matched = eng.prefix_cache.lookup(TOK.encode(LONG),
                                             max_len=len(TOK.encode(LONG)))
    assert probe is not None and matched >= 8
    eng.run()
    assert waiting.is_finished


# --------------------------------------------------------------------------- #
# per-class latency + /stats under concurrency
# --------------------------------------------------------------------------- #
def test_per_class_latency_in_snapshot(cfg):
    eng = InferenceEngine(cfg, max_batch=2, cache_len=128)
    eng.generate([_req("plain batch work", max_tokens=3),
                  _req("deadline", max_tokens=3, deadline_ms=60_000.0),
                  _req("missed", max_tokens=3, deadline_ms=0.0)])
    by_class = eng.scheduler.snapshot()["latency_by_class"]
    assert set(by_class) == {"batch", "interactive"}
    for cls in ("batch", "interactive"):
        row = by_class[cls]
        assert row["count"] >= 1
        assert row["ttft_p95_ms"] >= row["ttft_p50_ms"] >= 0.0
        assert row["e2e_p95_ms"] >= row["ttft_p50_ms"]
    assert by_class["interactive"]["deadline_missed"] == 1


def test_api_accepts_priority_and_deadline(cfg):
    from repro.serving.api import OpenAIServer

    eng = InferenceEngine(cfg, max_batch=1, cache_len=128)
    api = OpenAIServer(eng, "toy")
    greq = api._decode_chat({
        "messages": [{"role": "user", "content": "hi"}],
        "max_tokens": 2, "priority": 3, "deadline_ms": 250,
    })
    req = greq.to_requests(eng.tokenizer)[0]
    assert req.priority == 3 and req.deadline_ms == 250.0
    assert req.latency_class == "interactive"
    default = api._decode_chat(
        {"messages": [{"role": "user", "content": "hi"}]}
    ).to_requests(eng.tokenizer)[0]
    assert default.priority == 0 and default.deadline_ms is None
    assert default.latency_class == "batch"
    st = api.stats()
    assert st["sched_policy"] == "fifo"
    assert st["preemption"] is False and st["speculative_fill"] is True
    assert "latency_by_class" in st and "aborted" in st


def test_stats_snapshot_consistent_under_concurrent_mutation(cfg):
    """Hammer GET /stats from several threads while the engine loop admits,
    preempts, decodes and retires a deadline-mixed workload: every response
    must parse and carry the full key set (no torn reads, no 500s)."""
    from repro.serving.api import OpenAIServer
    from repro.serving.server import ApiServer

    eng = InferenceEngine(cfg, max_batch=2, cache_len=128,
                          sched_policy="edf", preemption=True)
    api = OpenAIServer(eng, "toy")
    server = ApiServer(api, port=0)
    server.start()
    url = f"http://127.0.0.1:{server.port}/stats"
    required = {"queue_depth", "oldest_wait_s", "latency_by_class",
                "sched_policy", "preemptions", "spec_chunks",
                "rows_per_wave", "host_syncs_per_token", "content_cache",
                "speculation"}
    failures = []
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            try:
                with urllib.request.urlopen(url, timeout=30) as resp:
                    body = json.loads(resp.read())
                missing = required - set(body)
                if missing:
                    failures.append(f"missing keys {missing}")
                for row in body["latency_by_class"].values():
                    if row["window"] > row["count"]:
                        failures.append("window exceeds lifetime count")
            except Exception as exc:        # noqa: BLE001 — collected
                failures.append(repr(exc))

    readers = [threading.Thread(target=hammer) for _ in range(3)]
    for t in readers:
        t.start()
    try:
        bodies = [{"messages": [{"role": "user", "content": f"load {i}"}],
                   "max_tokens": 4,
                   **({"deadline_ms": 50} if i % 2 else {})}
                  for i in range(8)]
        api.batch(bodies)
    finally:
        stop.set()
        for t in readers:
            t.join(timeout=10)
        server.stop()
        api.client.stop()
    assert not failures, failures[:5]


# --------------------------------------------------------------------------- #
# benchmark smoke
# --------------------------------------------------------------------------- #
def test_sched_policy_benchmark_smoke(tmp_path):
    from benchmarks import sched_policy, validate

    out = tmp_path / "BENCH_sched_policy.json"
    result = sched_policy.run(smoke=True, out=out)
    assert out.exists()
    assert validate.validate_payload(result, source=str(out)) == []
    variants = {r["variant"] for r in result["rows"]}
    assert variants == {v[0] for v in sched_policy.VARIANTS}
    for r in result["rows"]:
        assert r["tok_s"] > 0
        assert r["interactive_ttft_p95_ms"] >= r["interactive_ttft_p50_ms"]
    by = {r["variant"]: r for r in result["rows"]}
    assert by["edf_preempt"]["preemptions"] > 0
    assert by["fifo"]["spec_chunks"] > 0 >= by["fifo_nospec"]["spec_chunks"]
    # abort churn: requests really were cancelled mid-flight, their slots
    # were reclaimed, and the reclaim latency was measured in both abort
    # variants — with the reclaim hint cutting it (run() asserts the drop)
    for tag in ("fifo_abort", "fifo_abort_hint"):
        assert by[tag]["aborted"] > 0
        assert by[tag]["slot_reclaim_p50_ms"] > 0.0
    assert (by["fifo_abort_hint"]["slot_reclaim_p50_ms"]
            < by["fifo_abort"]["slot_reclaim_p50_ms"])
    assert all(r["aborted"] == 0 for r in result["rows"]
               if not r["variant"].startswith("fifo_abort"))


def test_validate_rejects_malformed_payloads():
    from benchmarks import validate

    good = {"name": "x", "schema_version": 1,
            "machine": {"platform": "p", "python": "3", "jax": "j",
                        "backend": "cpu", "device": "cpu"},
            "variants": ["a"], "rows": [{"variant": "a", "tok_s": 1.0}]}
    assert validate.validate_payload(good) == []
    for breakage in (
            lambda d: d.pop("machine"),
            lambda d: d.pop("variants"),
            lambda d: d.update(schema_version=0),
            lambda d: d.update(rows=[{"variant": "zzz", "tok_s": 1.0}]),
            lambda d: d.update(rows=[{"variant": "a", "note": "no metrics"}]),
    ):
        bad = json.loads(json.dumps(good))
        breakage(bad)
        assert validate.validate_payload(bad), breakage
    # every artifact-declaring benchmark module is registered in run.py
    assert validate.validate_registration() == []
    declared = validate.declared_artifacts()
    assert {"decode_loop", "prefill_overlap", "sched_policy"} <= set(declared)


def test_validate_directory_coverage_is_total():
    """Every benchmarks/*.py is infra, a registered BENCH artifact, or an
    explicitly-reasoned exemption — the validation step covers the whole
    directory, so a new untracked benchmark fails CI."""
    from pathlib import Path

    from benchmarks import validate

    assert validate.validate_directory_coverage() == []
    modules = {p.stem for p in Path(validate.__file__).parent.glob("*.py")}
    covered = (validate.INFRA_MODULES | set(validate.EXEMPT)
               | set(validate.declared_artifacts()))
    assert modules <= covered
    assert all(reason for reason in validate.EXEMPT.values())


def test_validate_baseline_throughput_gate(tmp_path):
    """--baseline mode: >tolerance aggregate-throughput regression fails,
    within-tolerance and speedups pass, mismatched variants fail."""
    from benchmarks import validate

    def payload(scale, variants=("a", "b")):
        return {"name": "x", "schema_version": 1,
                "machine": {"platform": "p", "python": "3", "jax": "j",
                            "backend": "cpu", "device": "cpu"},
                "variants": list(variants),
                "rows": [{"variant": v, "tok_s": t * scale}
                         for v, t in zip(variants, (100.0, 400.0))]}

    def write(name, **kw):
        p = tmp_path / name
        p.write_text(json.dumps(payload(**kw)))
        return p

    base = write("base.json", scale=1.0)
    assert validate.validate_baseline(write("same.json", scale=1.0),
                                      base, 0.15) == []
    assert validate.validate_baseline(write("fast.json", scale=1.3),
                                      base, 0.15) == []
    assert validate.validate_baseline(write("ok.json", scale=0.90),
                                      base, 0.15) == []
    errs = validate.validate_baseline(write("slow.json", scale=0.80),
                                      base, 0.15)
    assert errs and "regression" in errs[0]
    errs = validate.validate_baseline(
        write("drift.json", scale=1.0, variants=("a", "c")), base, 0.15)
    assert errs and "variant sets differ" in errs[0]
    # a dropped or collapsed cell must fail, never be silently excluded
    dropped = payload(1.0)
    dropped["rows"] = dropped["rows"][:1]
    p = tmp_path / "dropped.json"
    p.write_text(json.dumps(dropped))
    errs = validate.validate_baseline(p, base, 0.15)
    assert errs and "row counts differ" in errs[0]
    zeroed = payload(1.0)
    zeroed["rows"][1]["tok_s"] = 0.0
    p = tmp_path / "zeroed.json"
    p.write_text(json.dumps(zeroed))
    errs = validate.validate_baseline(p, base, 0.15)
    assert errs and "positive numeric 'tok_s'" in errs[0]
    # a regression measured on different hardware (gate keys mismatch)
    # warns instead of failing — the gate arms once baselines match
    other = payload(0.5)
    other["machine"]["cpu_count"] = 64
    p = tmp_path / "other_host.json"
    p.write_text(json.dumps(other))
    assert validate.validate_baseline(p, base, 0.15) == []
    agg = validate.aggregate_throughput(payload(1.0))
    assert abs(agg - 200.0) < 1e-9        # geomean of 100 and 400


# --------------------------------------------------------------------------- #
# anti-starvation aging: pinned worst-case wait bounds
# --------------------------------------------------------------------------- #
def test_priority_aging_wait_bound_is_gap_times_quantum():
    """Under sustained priority-p load, a priority-0 request waits at most
    ``p * aging_s`` before it outranks every fresh arrival — the lazy
    age boost climbs one level per quantum, so the bound is exactly the
    priority gap times the quantum (plus one admission round)."""
    pol = PriorityPolicy(aging_s=10.0)
    old = _req("starving batch work", priority=0)
    old.arrival_time = 1000.0
    gap_s = 5 * pol.aging_s                 # priority gap 5, quantum 10s
    fresh = _req("hot interactive", priority=5)
    fresh.arrival_time = old.arrival_time + gap_s - 0.01
    pol.tick(fresh.arrival_time)            # just inside the bound: loses
    assert pol.more_urgent(fresh, old)
    late = _req("hot interactive 2", priority=5)
    late.arrival_time = old.arrival_time + gap_s
    pol.tick(late.arrival_time)             # at the bound: aged one wins
    assert pol.more_urgent(old, late)


def test_priority_aging_disabled_restores_pure_priority():
    pol = PriorityPolicy(aging_s=0.0)
    old = _req("batch", priority=0)
    old.arrival_time = 0.0
    fresh = _req("chat", priority=5)
    fresh.arrival_time = 1e6                # waited "forever"
    pol.tick(fresh.arrival_time)
    assert pol.more_urgent(fresh, old)      # no aging: priority always wins


def test_edf_virtual_deadline_bounds_deadline_less_wait():
    """EDF gives deadline-less requests a virtual deadline of
    ``arrival + aging_horizon_s``: fresh tight-deadline arrivals whose real
    deadline lands beyond that horizon sort *behind* the aged batch
    request, so its worst-case wait is the horizon plus one round."""
    pol = EDFPolicy(aging_horizon_s=20.0)
    batch = _req("deadline-less batch")
    batch.arrival_time = 500.0              # virtual deadline: 520.0
    early = _req("tight deadline", deadline_ms=500.0)
    early.arrival_time = 519.0              # real deadline 519.5 < 520.0
    assert pol.more_urgent(early, batch)
    late = _req("tight deadline 2", deadline_ms=500.0)
    late.arrival_time = 520.1               # real deadline 520.6 > 520.0
    assert pol.more_urgent(batch, late)


def test_edf_infinite_horizon_restores_sort_behind_everything():
    import math
    pol = EDFPolicy(aging_horizon_s=math.inf)
    batch = _req("batch")
    batch.arrival_time = 0.0
    tight = _req("chat", deadline_ms=100.0)
    tight.arrival_time = 1e9
    assert pol.more_urgent(tight, batch)


# --------------------------------------------------------------------------- #
# abort/reclaim-aware decode-block planning
# --------------------------------------------------------------------------- #
def test_plan_decode_block_collapses_when_reclaim_queued():
    s = ContinuousBatchingScheduler(max_batch=4)
    for i in range(2):
        r = Request(prompt_tokens=[1, 2, 3],
                    sampling=SamplingParams(max_tokens=32))
        s.add(r)
    s.admit([0, 1])
    assert s.plan_decode_block(8) == 8              # full block available
    assert s.plan_decode_block(8, reclaim_queued=True) == 1
    s.add(Request(prompt_tokens=[4], sampling=SamplingParams(max_tokens=4)))
    assert s.plan_decode_block(8) == 1              # pending also collapses


def test_engine_reclaim_hint_collapses_live_block(cfg):
    """With ``reclaim_hint`` installed (as EngineClient does while an
    abort waits at the block boundary), a step that would run a full
    K-token block runs exactly one device step instead."""
    eng = InferenceEngine(cfg, max_batch=2, cache_len=128,
                          max_decode_block=8, enable_prefix_cache=False,
                          enable_content_cache=False)
    eng.add_request(_req("collapse this block", max_tokens=32))
    while not eng._live_slots:              # admit + prefill
        eng.step()
    before = eng.scheduler.stats.device_steps
    eng.step()
    assert eng.scheduler.stats.device_steps - before == 8
    eng.reclaim_hint = lambda: True
    before = eng.scheduler.stats.device_steps
    eng.step()
    assert eng.scheduler.stats.device_steps - before == 1
    eng.reclaim_hint = None
    before = eng.scheduler.stats.device_steps
    eng.step()
    assert eng.scheduler.stats.device_steps - before == 8
    eng.abort(next(iter(eng.scheduler.active.values())).request_id)
