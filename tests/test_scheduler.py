"""Scheduler semantics (paper Algorithm 1) + property tests."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(optional dev dep — see tests/README.md)")
from hypothesis import given, settings, strategies as st

from repro.core.request import Request, SamplingParams
from repro.core.scheduler import ContinuousBatchingScheduler


def _req(i=0):
    return Request(prompt_tokens=[1, 2, i], sampling=SamplingParams())


def test_admit_fills_free_slots_in_fifo_order():
    s = ContinuousBatchingScheduler(max_batch=2)
    r1, r2, r3 = _req(1), _req(2), _req(3)
    for r in (r1, r2, r3):
        s.add(r)
    admitted = s.admit([0, 1])
    assert [r.request_id for _, r in admitted] == [r1.request_id,
                                                   r2.request_id]
    assert s.num_active == 2 and len(s.pending) == 1


def test_retire_frees_slot_for_next_request():
    s = ContinuousBatchingScheduler(max_batch=1)
    r1, r2 = _req(1), _req(2)
    s.add(r1)
    s.add(r2)
    s.admit([0])
    got = s.retire(0)
    assert got is r1
    admitted = s.admit([0])
    assert admitted[0][1] is r2


def test_admit_respects_max_batch():
    s = ContinuousBatchingScheduler(max_batch=2)
    for i in range(5):
        s.add(_req(i))
    admitted = s.admit([0, 1, 2, 3])        # more slots offered than allowed
    assert len(admitted) == 2


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(["add", "admit", "retire"]),
                min_size=1, max_size=60),
       st.integers(1, 4))
def test_scheduler_invariants(ops, max_batch):
    """active <= max_batch always; every request ends in exactly one place."""
    s = ContinuousBatchingScheduler(max_batch=max_batch)
    next_slot = list(range(max_batch))
    occupied = {}
    n_added = n_retired = 0
    for op in ops:
        if op == "add":
            s.add(_req(n_added))
            n_added += 1
        elif op == "admit" and next_slot:
            admitted = s.admit(list(next_slot))
            for slot, r in admitted:
                next_slot.remove(slot)
                occupied[slot] = r
        elif op == "retire" and occupied:
            slot = next(iter(occupied))
            s.retire(slot)
            del occupied[slot]
            next_slot.append(slot)
            n_retired += 1
        assert s.num_active <= max_batch
        assert s.num_active == len(occupied)
    assert s.num_active + len(s.pending) + n_retired == n_added
