"""Sharding rules: spec assignment, divisibility sanitisation, and a real
jit lowering through the specs machinery on a 1x1 mesh (the full 16x16 /
2x16x16 meshes are exercised by launch/dryrun.py, which owns the 512-device
flag)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import default_rules, param_shardings, use_sharding
from repro.distributed.sharding import sanitize_spec
from repro.launch.specs import build_step_spec, shape_rules
from repro.models import build_model


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_specs_assigned_by_name():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    shapes = model.init_shapes()
    mesh = _mesh11()
    rules = default_rules(mesh, fsdp=True)
    sh = param_shardings(shapes, mesh, rules)
    # attention projection: fsdp x tp (leading None = stacked layer dim)
    blk = sh["block"]["pos0"]["attn"]
    assert blk["wq"].spec == P(None, "data", "model")
    assert blk["wo"].spec == P(None, "model", "data")
    # norms replicated (P(None) == unsharded dim)
    assert sh["final_ln"].spec in (P(), P(None))


def test_stacked_leading_dims_get_none():
    cfg = get_config("grok-1-314b").reduced()
    shapes = build_model(cfg).init_shapes()
    mesh = _mesh11()
    sh = param_shardings(shapes, mesh, default_rules(mesh, fsdp=True))
    we = sh["block"]["pos0"]["moe"]["we_gate"]      # [R, E, D, F]
    assert we.spec == P(None, None, "data", "model")


def test_sanitize_spec_drops_nondivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 1x1 mesh divides everything — use shape logic directly via a fake
    spec = sanitize_spec(P("data", "model"), (10, 16), mesh)
    assert spec == P("data", "model")               # 1 divides all

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = sanitize_spec(P("data", "model"), (50280, 32), FakeMesh())
    assert spec == P(None, "model")                 # 50280 % 16 != 0


def test_constrain_is_noop_without_mesh():
    from repro.distributed import constrain
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", "tp")
    np.testing.assert_array_equal(x, y)


def test_step_specs_lower_on_host_mesh():
    """End-to-end: every step kind lowers+compiles through the dry-run glue
    (reduced config, 1x1 mesh, tiny shapes injected)."""
    cfg = get_config("qwen2-0.5b").reduced()
    mesh = _mesh11()
    import repro.launch.specs as specs_mod
    saved = dict(specs_mod.SHAPES)
    specs_mod.SHAPES = {
        "train_4k": dict(seq=32, batch=2, kind="train"),
        "prefill_32k": dict(seq=32, batch=2, kind="prefill"),
        "decode_32k": dict(seq=32, batch=2, kind="decode"),
        "long_500k": dict(seq=64, batch=1, kind="decode"),
    }
    try:
        for shape in specs_mod.SHAPES:
            rules = shape_rules(cfg, shape, mesh, fsdp=False)
            spec = build_step_spec(cfg, shape)
            with use_sharding(mesh, rules):
                jitted = jax.jit(
                    spec.fn, in_shardings=spec.in_shardings(mesh, rules),
                    out_shardings=spec.out_shardings(mesh, rules),
                    donate_argnums=spec.donate_argnums)
                compiled = jitted.lower(*spec.args).compile()
            assert compiled.cost_analysis() is not None
    finally:
        specs_mod.SHAPES = saved


def test_shape_rules_long_context():
    cfg = get_config("yi-34b")
    mesh = _mesh11()
    rules = shape_rules(cfg, "long_500k", mesh)
    assert rules["batch"] is None                   # batch=1: no data shard
    assert "model" in rules["kv_seq"]
    assert rules["fsdp"] == "data"                  # 34B > threshold
