"""Speculative decoding (PR 9): draft-verify inside the compiled decode
block.  Greedy/seeded ngram rounds must be TOKEN-IDENTICAL to --spec-mode
off (the match rule couples the verifier to the plain per-token key stream);
the draft-model rung is held to the host rejection-sampling reference; KV
rollback must leak nothing on either cache layout."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.engine import InferenceEngine
from repro.core.kv_cache import (SlotKVPool, admit_decode_state,
                                 init_decode_state, select_cache_slots)
from repro.core.request import Request, SamplingParams
from repro.core.sampling import request_base_key
from repro.core.spec_decode import (NGramProposer, SpecController,
                                    build_spec_verify_fn, stage_drafts,
                                    verify_reference)
from repro.serving.tokenizer import ByteTokenizer

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # tier-1 collects without hypothesis (CI has it)
    HAS_HYPOTHESIS = False

TOK = ByteTokenizer()

# repetition-heavy prompt: prompt-lookup drafting finds long continuations
REP = "the cat sat on the mat and the cat sat on the mat again and "
MIX = "zq pw lx " + REP


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3-0.6b-toy")


def _mk(cfg, *, max_batch=3, K=8, seed=0, **kw):
    return InferenceEngine(cfg, max_batch=max_batch, cache_len=256, seed=seed,
                           max_decode_block=K, enable_prefix_cache=False, **kw)


def _reqs(n_tok=24, **kw):
    """A mixed batch: repetition-heavy greedy, short greedy, seeded
    stochastic — different budgets so slots retire at different rounds."""
    return [
        Request(prompt_tokens=TOK.encode(REP),
                sampling=SamplingParams(max_tokens=n_tok, **kw)),
        Request(prompt_tokens=TOK.encode("short one"),
                sampling=SamplingParams(max_tokens=n_tok // 2, **kw)),
        Request(prompt_tokens=TOK.encode(MIX),
                sampling=SamplingParams(max_tokens=n_tok, temperature=0.9,
                                        top_p=0.9, seed=42)),
    ]


# --------------------------------------------------------------------------- #
# n-gram proposer (host)
# --------------------------------------------------------------------------- #
def test_ngram_proposer_prompt_lookup():
    p = NGramProposer(max_n=3)
    #       0  1  2  3  4  5  6  7
    hist = [5, 6, 7, 9, 5, 6, 7, 9]      # trailing [6,7,9] recurs at 1..3
    assert p.propose(hist + [5], 3) == [6, 7, 9]
    assert p.propose(hist + [5], 2) == [6, 7]
    # no recurrence anywhere -> no proposal
    assert p.propose([1, 2, 3, 4, 5], 4) == []
    # most recent occurrence wins over an earlier different continuation
    assert p.propose([1, 9, 2, 1, 9, 3, 1, 9], 1) == [3]
    assert p.propose([], 4) == [] and p.propose([7], 4) == []


# --------------------------------------------------------------------------- #
# tentpole bit-identity: greedy + seeded ngram == off
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("K", [1, 8])
def test_ngram_token_identical_to_off_across_block_sizes(cfg, K):
    ref = [r.output_tokens for r in _mk(cfg, K=K).generate(_reqs())]
    eng = _mk(cfg, K=K, spec_mode="ngram", spec_k=4)
    got = eng.generate(_reqs())
    assert [r.output_tokens for r in got] == ref
    assert all(r.finish_reason is not None for r in got)
    stats = eng.speculation_stats()
    assert stats["rounds"] > 0 and stats["tokens_drafted"] > 0
    assert stats["tokens_accepted"] + stats["tokens_rejected"] \
        == stats["tokens_drafted"]


def test_ngram_identical_to_off_solo_vs_batched(cfg):
    """Per-slot streams must not depend on batch composition with spec on
    (staged neighbours, seq_valid masking, per-slot rollback)."""
    solo = []
    for r in _reqs():
        eng = _mk(cfg, max_batch=1, spec_mode="ngram", spec_k=4)
        eng.generate([r])
        solo.append(r.output_tokens)
    batched = _mk(cfg, spec_mode="ngram", spec_k=4).generate(_reqs())
    assert [r.output_tokens for r in batched] == solo


def test_seeded_stochastic_ngram_replays_spec_off_stream(cfg):
    """The match rule samples targets with the plain per-token keys, so even
    a *stochastic* seeded ngram request is bit-identical to spec off."""
    r_off = Request(prompt_tokens=TOK.encode(MIX),
                    sampling=SamplingParams(max_tokens=20, temperature=0.9,
                                            top_p=0.9, seed=7))
    _mk(cfg).generate([r_off])
    r_on = Request(prompt_tokens=TOK.encode(MIX),
                   sampling=SamplingParams(max_tokens=20, temperature=0.9,
                                           top_p=0.9, seed=7))
    _mk(cfg, spec_mode="ngram", spec_k=4).generate([r_on])
    assert r_on.output_tokens == r_off.output_tokens
    assert len(set(r_on.output_tokens)) > 1


def test_ngram_identical_under_preemption_and_resume(cfg):
    """Spec rounds + preemption: a preempted-and-resumed slot re-enters
    speculation (EWMA reset, drafts from committed history) and still emits
    the exact spec-off stream."""
    def load(**kw):
        longs = [Request(prompt_tokens=TOK.encode(REP),
                         sampling=SamplingParams(max_tokens=30))
                 for _ in range(3)]
        vip = Request(prompt_tokens=TOK.encode("urgent"),
                      sampling=SamplingParams(max_tokens=8), priority=5)
        return longs, vip

    outs = []
    for spec in ({}, {"spec_mode": "ngram", "spec_k": 4}):
        eng = _mk(cfg, max_batch=2, sched_policy="priority",
                  preemption=True, **spec)
        longs, vip = load()
        for r in longs:
            eng.add_request(r)
        eng.step()
        eng.add_request(vip)        # evicts a running long request
        while not all(r.is_finished for r in longs + [vip]):
            eng.step()
        assert sum(r.preempt_count for r in longs) > 0
        outs.append([r.output_tokens for r in longs + [vip]])
    assert outs[0] == outs[1]


def test_ngram_paged_layout_identical_and_leaks_no_pages(cfg):
    kw = dict(kv_layout="paged", kv_page_size=16,
              enable_content_cache=False)
    ref = [r.output_tokens
           for r in _mk(cfg, **kw).generate(_reqs())]
    eng = _mk(cfg, spec_mode="ngram", spec_k=4, **kw)
    free0 = eng.pool.allocator.num_free
    got = eng.generate(_reqs())
    assert [r.output_tokens for r in got] == ref
    # every page returned after retire: rejected-tail cells live on
    # slot-owned pages, so rollback can never strand a page refcount
    assert eng.pool.allocator.num_free == free0
    assert eng.speculation_stats()["rounds"] > 0


def test_paged_exhaustion_with_spec_active(cfg):
    """Page-arena pressure while speculating: capacity for spec_k+1 steps is
    ensured per round (preempting if needed) and every request completes."""
    eng = _mk(cfg, max_batch=3, kv_layout="paged", kv_page_size=16,
              kv_num_pages=24, enable_content_cache=False,
              spec_mode="ngram", spec_k=4, preemption=True)
    free0 = eng.pool.allocator.num_free
    reqs = [Request(prompt_tokens=TOK.encode(REP),
                    sampling=SamplingParams(max_tokens=40))
            for _ in range(4)]
    done = eng.generate(reqs)
    assert all(r.is_finished for r in done)
    assert eng.pool.allocator.num_free == free0


# --------------------------------------------------------------------------- #
# draft-model rung
# --------------------------------------------------------------------------- #
def test_draft_model_oracle_accepts_and_matches_greedy(cfg):
    """Draft == target (same config AND params): greedy rows must emit the
    exact spec-off stream with high acceptance (only numeric drift between
    the draft's own KV path and the target's can reject)."""
    ref_eng = _mk(cfg)
    ref = ref_eng.generate(_reqs())
    eng = _mk(cfg, spec_mode="draft", spec_k=4, spec_draft_config=cfg,
              spec_draft_params=ref_eng.params)
    eng.params = ref_eng.params
    got = eng.generate(_reqs())
    for a, b in zip(ref[:2], got[:2]):          # the two greedy rows
        assert a.output_tokens == b.output_tokens
    stats = eng.speculation_stats()
    assert stats["acceptance_rate"] > 0.3
    assert stats["draft_pool_bytes"] > 0


def test_draft_model_stochastic_seeded_replay(cfg):
    """The rejection-sampled stream is NOT the spec-off stream (different
    coupling), but it must be a valid completion and replay bit-identically
    for a fixed seed across engine instances."""
    def run():
        eng = _mk(cfg, spec_mode="draft", spec_k=4, spec_draft_config=cfg)
        r = Request(prompt_tokens=TOK.encode(MIX),
                    sampling=SamplingParams(max_tokens=20, temperature=0.9,
                                            top_p=0.9, seed=42))
        eng.generate([r])
        assert r.is_finished and len(r.output_tokens) == 20
        return r.output_tokens
    assert run() == run()


def test_draft_model_requires_matching_vocab(cfg):
    bad = dataclasses.replace(cfg, vocab_size=cfg.vocab_size * 2)
    with pytest.raises(ValueError, match="vocab"):
        _mk(cfg, spec_mode="draft", spec_draft_config=bad)


# --------------------------------------------------------------------------- #
# K adaptation + stats plumbing
# --------------------------------------------------------------------------- #
def test_controller_probation_and_recovery():
    c = SpecController(alpha=0.5, probation_rounds=4)
    c.on_admit(0)
    assert c.tick() == 1.0
    for _ in range(8):
        c.observe(0, 4, 0)          # everything rejected
    assert c.round_acceptance() < 0.15
    assert c.tick(low_water=0.15) == 0.0      # probation entered
    for _ in range(4):
        assert c.tick() == 0.0                # cooldown holds
    assert c.tick() == 1.0                    # expiry resets optimistic
    c.release(0)
    assert c.snapshot() == {}


def test_scheduler_gates_spec_under_pressure(cfg):
    eng = _mk(cfg, max_batch=2, spec_mode="ngram", spec_k=4)
    s = eng.scheduler
    assert s.plan_spec_k(4, 1.0) == 0         # no active slots yet
    for r in _reqs()[:2]:
        eng.add_request(r)
    for _ in range(20):                       # step until prefills commit
        eng.step()
        if len(s.active) == 2 and not s.pending and not s.chunk_queue:
            break
    assert s.plan_spec_k(4, 1.0) == 4
    assert s.plan_spec_k(4, 0.3) == 2         # low acceptance halves K
    assert s.plan_spec_k(4, 0.1) == 0         # below low-water: off
    assert s.plan_spec_k(4, 1.0, reclaim_queued=True) == 0
    eng.add_request(_reqs()[0])               # pending pressure (batch full)
    assert s.plan_spec_k(4, 1.0) == 0


def test_speculation_stats_shape(cfg):
    eng = _mk(cfg, spec_mode="ngram", spec_k=4)
    eng.generate(_reqs())
    s = eng.speculation_stats()
    for k in ("mode", "k", "rounds", "tokens_drafted", "tokens_accepted",
              "tokens_rejected", "tokens_emitted", "acceptance_rate",
              "slot_acceptance_ewma", "draft_pool_bytes"):
        assert k in s
    off = _mk(cfg).speculation_stats()
    assert off["mode"] == "off" and off["rounds"] == 0


def test_logprobs_through_spec_rounds(cfg):
    """Per-token logprobs requested with spec on: same tokens AND same
    logprob values as spec off (the verify pass computes them from the same
    per-position logits)."""
    def run(**kw):
        r = Request(prompt_tokens=TOK.encode(REP),
                    sampling=SamplingParams(max_tokens=12, logprobs=True,
                                            top_logprobs=2))
        _mk(cfg, **kw).generate([r])
        return r
    a, b = run(), run(spec_mode="ngram", spec_k=4)
    assert a.output_tokens == b.output_tokens
    assert len(b.output_logprobs) == len(b.output_tokens)
    for (lp_a, top_a), (lp_b, top_b) in zip(a.output_logprobs,
                                            b.output_logprobs):
        assert lp_a == pytest.approx(lp_b, abs=1e-5)
        assert [t for t, _ in top_a] == [t for t, _ in top_b]


# --------------------------------------------------------------------------- #
# hypothesis property: compiled verify round == host reference
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def target(cfg):
    eng = InferenceEngine(cfg, max_batch=1, cache_len=64, max_decode_block=1,
                          enable_prefix_cache=False)
    # the target's own greedy continuation of the property prompt: perfect
    # drafts, driving the full-acceptance (+bonus) path
    r = Request(prompt_tokens=TOK.encode("property test prompt"),
                sampling=SamplingParams(max_tokens=5))
    eng.generate([r])
    return eng.model, eng.params, np.asarray(r.output_tokens[:4], np.int32)


def _seeded_round(cfg_obj, model, params, *, spec_k, drafts, temperature,
                  top_p, top_k, seed, use_q, q_eps):
    """Run ONE verify round on a hand-built slot and return
    (device_emitted, host_emitted)."""
    cache_len, B = 64, 2
    prompt = TOK.encode("property test prompt")
    ln = len(prompt)
    pool = SlotKVPool(cfg_obj, B, cache_len)

    bucket = 32
    toks = np.zeros((1, bucket), np.int32)
    toks[0, :ln - 1] = prompt[:-1]

    @jax.jit
    def prime(params, cache, toks):
        pos = jnp.arange(bucket)[None, :]
        sv = (jnp.arange(bucket) < ln - 1)[None, :]
        out = model.apply(params, toks, mode="prefill", positions=pos,
                          cache=cache, seq_valid=sv, logits_mode="last")
        return out.cache

    row = prime(params, pool.single_cache_zeros(), jnp.asarray(toks))
    pool.insert(0, row)

    base_key = request_base_key(seed)
    state = init_decode_state(B, 0, 1, spec_k=spec_k)
    state = admit_decode_state(
        state, jnp.asarray([0], jnp.int32),
        jnp.asarray([prompt[-1]], jnp.int32),
        jnp.asarray([ln - 1], jnp.int32),
        jnp.asarray([temperature], jnp.float32),
        jnp.asarray([top_p], jnp.float32),
        jnp.asarray([top_k], jnp.int32), jnp.asarray([0.0], jnp.float32),
        jnp.asarray(base_key[None, :]), jnp.zeros((1, 1), bool),
        jnp.asarray([100], jnp.int32),
        jnp.full((1, 1), -1, jnp.int32), jnp.asarray([True]))

    d_host = np.zeros((B, spec_k), np.int32)
    d_host[0] = drafts
    lens = np.zeros((B,), np.int32)
    lens[0] = spec_k
    # draft "quality" knob: q = eps-smoothed point mass on the draft token
    V = cfg_obj.vocab_size
    q = None
    if use_q:
        q_np = np.full((B, spec_k, V), q_eps / V, np.float32)
        for j, d in enumerate(drafts):
            q_np[0, j, d] += 1.0 - q_eps
        q = jnp.asarray(q_np)

    # host reference: run the target per token over [last, d_0..d_{k-1}]
    ref_cache = {k: v for k, v in pool.cache.items()}
    logits_rows = []
    tok_in = jnp.asarray([prompt[-1]] + list(drafts), jnp.int32)
    act = jnp.asarray([True, False])
    for j in range(spec_k + 1):
        pos = jnp.asarray([ln - 1 + j, 0], jnp.int32)
        inp = jnp.stack([tok_in[j], jnp.int32(0)])
        out = model.apply(params, inp[:, None], mode="decode",
                          positions=pos[:, None], cache=ref_cache)
        ref_cache = select_cache_slots(act, pos, out.cache, ref_cache)
        logits_rows.append(np.asarray(out.logits[0, 0], np.float32))
    host = verify_reference(np.stack(logits_rows), drafts,
                            None if q is None else np.asarray(q[0]),
                            base_key, ln - 1, temperature, top_p, top_k,
                            0.0, use_q)

    verify = build_spec_verify_fn(model, use_ctx=False, n_top=0,
                                  paged=False, cache_len=cache_len)
    state = stage_drafts(state, jnp.asarray(d_host), jnp.asarray(lens))
    _, _, emit, _, _, _ = verify(params, pool.cache, state, q,
                                 spec_k=spec_k, use_q=use_q)
    col = np.asarray(emit)[:, 0]
    device = [int(t) for t in col if t >= 0]
    return device, host


if HAS_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(data=st.data())
    def test_verify_round_matches_host_reference(cfg, target, data):
        """Arbitrary (draft quality, k_draft, sampler) mixes: the compiled
        batched verify round emits exactly what the run-target-per-token
        host reference does — match rule and rejection-correction rule
        alike."""
        spec_k = data.draw(st.integers(1, 4), label="k")
        seed = data.draw(st.integers(0, 2**32), label="seed")
        temperature = data.draw(st.sampled_from([0.0, 0.7, 1.3]),
                                label="temp")
        top_p = data.draw(st.sampled_from([1.0, 0.9]), label="top_p")
        top_k = data.draw(st.sampled_from([0, 40]), label="top_k")
        use_q = data.draw(st.booleans(), label="use_q")
        q_eps = data.draw(st.sampled_from([0.05, 0.9]), label="q_eps")
        rng = np.random.default_rng(seed)
        model, params, oracle = target
        quality = data.draw(st.sampled_from(["random", "oracle", "mixed"]),
                            label="draft quality")
        if quality == "random":
            drafts = rng.integers(0, cfg.vocab_size, spec_k).astype(np.int32)
        elif quality == "oracle":   # the target's own continuation
            drafts = oracle[:spec_k]
        else:                       # good prefix, garbage tail
            drafts = oracle[:spec_k].copy()
            drafts[-1] = rng.integers(0, cfg.vocab_size)
        device, host = _seeded_round(
            cfg, model, params, spec_k=spec_k, drafts=drafts,
            temperature=temperature, top_p=top_p, top_k=top_k, seed=seed,
            use_q=use_q, q_eps=q_eps)
        assert device == host
