"""UTF-8-safe streaming (paper §3.2): never split a code point, lose no
bytes, for arbitrary text and arbitrary chunking."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(optional dev dep — see tests/README.md)")
from hypothesis import given, settings, strategies as st

from repro.core.streaming import StreamDecoder, TokenStreamDecoder
from repro.serving.tokenizer import ByteTokenizer


@settings(max_examples=60, deadline=None)
@given(st.text(min_size=0, max_size=120),
       st.lists(st.integers(1, 7), min_size=1, max_size=40))
def test_stream_decoder_reassembles_exactly(text, cuts):
    data = text.encode("utf-8")
    dec = StreamDecoder()
    out, pos, i = [], 0, 0
    while pos < len(data):
        step = cuts[i % len(cuts)]
        out.append(dec.push(data[pos:pos + step]))
        pos += step
        i += 1
    out.append(dec.flush())
    assert "".join(out) == text


def test_multibyte_split_is_held_back():
    dec = StreamDecoder()
    euro = "€".encode("utf-8")          # 3 bytes
    assert dec.push(euro[:1]) == ""
    assert dec.push(euro[1:2]) == ""
    assert dec.push(euro[2:]) == "€"


@settings(max_examples=40, deadline=None)
@given(st.text(min_size=1, max_size=60))
def test_token_stream_decoder_roundtrip(text):
    tok = ByteTokenizer()
    dec = TokenStreamDecoder(tok)
    tokens = tok.encode(text, add_bos=False)
    got = dec.push_tokens(tokens) + dec.flush()
    assert got == text


def test_specials_emit_nothing():
    tok = ByteTokenizer()
    dec = TokenStreamDecoder(tok)
    assert dec.push_token(tok.EOS) == ""
    assert dec.push_token(tok.BOS) == ""
