"""UTF-8-safe streaming (paper §3.2): never split a code point, lose no
bytes, for arbitrary text and arbitrary chunking — and stop-sequence
filtering that matches non-streaming truncation for arbitrary chunking."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(optional dev dep — see tests/README.md)")
from hypothesis import given, settings, strategies as st

from repro.core.streaming import (StopSequenceChecker, StreamDecoder,
                                  TokenStreamDecoder)
from repro.serving.tokenizer import ByteTokenizer


@settings(max_examples=60, deadline=None)
@given(st.text(min_size=0, max_size=120),
       st.lists(st.integers(1, 7), min_size=1, max_size=40))
def test_stream_decoder_reassembles_exactly(text, cuts):
    data = text.encode("utf-8")
    dec = StreamDecoder()
    out, pos, i = [], 0, 0
    while pos < len(data):
        step = cuts[i % len(cuts)]
        out.append(dec.push(data[pos:pos + step]))
        pos += step
        i += 1
    out.append(dec.flush())
    assert "".join(out) == text


def test_multibyte_split_is_held_back():
    dec = StreamDecoder()
    euro = "€".encode("utf-8")          # 3 bytes
    assert dec.push(euro[:1]) == ""
    assert dec.push(euro[1:2]) == ""
    assert dec.push(euro[2:]) == "€"


@settings(max_examples=40, deadline=None)
@given(st.text(min_size=1, max_size=60))
def test_token_stream_decoder_roundtrip(text):
    tok = ByteTokenizer()
    dec = TokenStreamDecoder(tok)
    tokens = tok.encode(text, add_bos=False)
    got = dec.push_tokens(tokens) + dec.flush()
    assert got == text


def test_specials_emit_nothing():
    tok = ByteTokenizer()
    dec = TokenStreamDecoder(tok)
    assert dec.push_token(tok.EOS) == ""
    assert dec.push_token(tok.BOS) == ""


@settings(max_examples=80, deadline=None)
@given(st.text(alphabet="abcXY ", min_size=0, max_size=80),
       st.lists(st.text(alphabet="abcXY ", min_size=1, max_size=5),
                min_size=1, max_size=3),
       st.lists(st.integers(1, 5), min_size=1, max_size=20))
def test_stop_checker_matches_offline_truncation(text, stops, cuts):
    """Streaming through StopSequenceChecker must equal the offline rule:
    truncate at the earliest occurrence of any stop sequence — regardless
    of how the text is chunked, and never emitting a match prefix that
    later completes."""
    chk = StopSequenceChecker(stops)
    out, pos, i, stopped = [], 0, 0, False
    while pos < len(text) and not stopped:
        step = cuts[i % len(cuts)]
        emitted, stopped = chk.push(text[pos:pos + step])
        out.append(emitted)
        pos += step
        i += 1
    if not stopped:
        out.append(chk.flush())
    got = "".join(out)

    # offline rule: the match that *completes* first wins (min end, then
    # min start) — the streaming semantics, chunking-invariant
    hits = [(text.find(s) + len(s), text.find(s)) for s in stops
            if text.find(s) != -1]
    want = text[:min(hits)[1]] if hits else text
    assert got == want
    assert stopped == bool(hits)


def test_stop_checker_holds_back_partial_match():
    chk = StopSequenceChecker(["END"])
    assert chk.push("abcE") == ("abc", False)     # "E" could become "END"
    assert chk.push("N") == ("", False)           # still ambiguous
    assert chk.push("!") == ("EN!", False)        # disproven: released
    emitted, stopped = chk.push("xEND trailing")
    assert (emitted, stopped) == ("x", True)      # match + tail truncated
