"""End-to-end behaviour tests: OpenAI-compatible API over HTTP, streaming,
and the full serve loop — the paper's §3 surface as a user sees it."""
import json
import urllib.request

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import InferenceEngine
from repro.serving.api import OpenAIServer
from repro.serving.media import encode_b64
from repro.serving.server import ApiServer


@pytest.fixture(scope="module")
def api():
    cfg = get_config("qwen3-0.6b-toy")
    engine = InferenceEngine(cfg, max_batch=4, cache_len=128)
    return OpenAIServer(engine, "qwen3-0.6b-toy")


def test_chat_completion_contract(api):
    resp = api.chat_completion({
        "model": "qwen3-0.6b-toy",
        "messages": [{"role": "user", "content": "hello"}],
        "max_tokens": 6,
    })
    assert resp["object"] == "chat.completion"
    assert resp["choices"][0]["finish_reason"] in ("stop", "length")
    assert resp["usage"]["completion_tokens"] >= 1
    assert isinstance(resp["choices"][0]["message"]["content"], str)


def test_streaming_chunks(api):
    chunks = list(api.chat_completion_stream({
        "messages": [{"role": "user", "content": "hi"}],
        "max_tokens": 5,
    }))
    assert len(chunks) >= 1
    assert chunks[-1]["choices"][0]["finish_reason"] in ("stop", "length")
    assert all(c["object"] == "chat.completion.chunk" for c in chunks)


def test_batch_endpoint_concurrency(api):
    bodies = [{"messages": [{"role": "user", "content": f"q{i}"}],
               "max_tokens": 4} for i in range(6)]
    out = api.batch(bodies)
    assert len(out) == 6
    assert all(o["usage"]["completion_tokens"] >= 1 for o in out)


def test_multimodal_message_content():
    cfg = get_config("qwen3-vl-toy")
    engine = InferenceEngine(cfg, max_batch=2, cache_len=128,
                             vision_work_iters=2)
    api = OpenAIServer(engine, "qwen3-vl-toy")
    img = np.random.default_rng(0).integers(0, 255, (16, 16, 3),
                                            dtype=np.uint8)
    b64 = encode_b64(img)["base64"]
    body = {
        "messages": [{"role": "user", "content": [
            {"type": "text", "text": "what is this?"},
            {"type": "image_url",
             "image_url": {"url": f"data:image/npy;base64,{b64}"}},
        ]}],
        "max_tokens": 4,
    }
    r1 = api.chat_completion(body)
    r2 = api.chat_completion(body)      # second turn: content-cache hit
    assert r1["choices"][0]["message"]["content"] == \
        r2["choices"][0]["message"]["content"]
    assert engine.content_cache.stats.hits >= 1


def test_http_server_roundtrip():
    cfg = get_config("qwen3-0.6b-toy")
    engine = InferenceEngine(cfg, max_batch=2, cache_len=128)
    server = ApiServer(OpenAIServer(engine, "m"), port=0)
    server.start()
    try:
        url = f"http://127.0.0.1:{server.port}"
        with urllib.request.urlopen(url + "/v1/models") as r:
            models = json.load(r)
        assert models["data"][0]["id"] == "m"
        req = urllib.request.Request(
            url + "/v1/chat/completions",
            data=json.dumps({
                "messages": [{"role": "user", "content": "ping"}],
                "max_tokens": 4}).encode(),
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req) as r:
            resp = json.load(r)
        assert resp["choices"][0]["message"]["content"] is not None
    finally:
        server.stop()
