"""Training substrate: optimizer math, loss goes down, checkpoint roundtrip,
data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.training.checkpoint import (checkpoint_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import BigramDataPipeline
from repro.training.optimizer import (AdamWConfig, adamw_update, global_norm,
                                      init_opt_state, lr_at)
from repro.training.train_step import init_train_state, make_train_step


def test_adamw_minimises_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}              # d/dw of w^2
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr_at(cfg, jnp.asarray(100))) <= 0.11
    assert float(lr_at(cfg, jnp.asarray(5))) < 1.0


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)        # lr=0: only test metrics
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    _, _, metrics = adamw_update(cfg, params, {"w": jnp.full(3, 100.0)}, opt)
    assert float(metrics["grad_norm"]) > 1.0        # unclipped norm reported


def test_loss_decreases_over_training():
    cfg = get_config("qwen3-0.6b-toy")
    # data vocab 256 << model vocab: each bigram transition is visited many
    # times in 25 steps, so generalisation (not just memorisation) is
    # measurable quickly
    data = BigramDataPipeline(256, seq_len=64, batch_size=8)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40),
        remat=False), donate_argnums=(0,))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_moe_aux_loss_present():
    cfg = get_config("qwen3-30b-a3b-toy")
    data = BigramDataPipeline(cfg.vocab_size, seq_len=32, batch_size=2)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(), remat=False))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    _, metrics = step(state, batch)
    assert float(metrics["aux_loss"]) > 0.0


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen3-0.6b-toy").reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, state, step=7)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored = restore_checkpoint(path, like)
    assert checkpoint_step(path) == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_and_learnable():
    p1 = BigramDataPipeline(100, 32, 4, seed=3)
    p2 = BigramDataPipeline(100, 32, 4, seed=3)
    b1, b2 = p1.batch(5), p2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # structure exists: successor entropy is far below uniform
    toks = np.concatenate([p1.batch(i)["tokens"].ravel() for i in range(20)])
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(a), set()).add(int(b))
    mean_succ = np.mean([len(v) for v in pairs.values()])
    assert mean_succ < 30, "bigram structure missing"


def test_global_norm():
    assert abs(float(global_norm({"a": jnp.array([3.0, 4.0])})) - 5.0) < 1e-6
